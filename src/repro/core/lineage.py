"""The lineage graph — MGit's main data structure (paper §3, Tables 1-2).

Nodes are models; *provenance* edges track how models are derived from each
other; *versioning* edges link consecutive versions of one model. Nodes carry
optional creation functions (how to rebuild the model from its parents) and
test functions. The graph serializes its metadata to JSON at the end of every
mutating operation (mirroring the paper's CLI/Python dual interface), while
parameters live in the storage layer (``repro.store``).
"""

from __future__ import annotations

import dataclasses
import fnmatch
import json
import os
import re
import warnings
from typing import Any, Callable, Dict, Iterable, Iterator, List, Optional, Sequence

from repro.core.artifact import ModelArtifact

# ---------------------------------------------------------------------------
# Creation functions
# ---------------------------------------------------------------------------

# Registry so creation functions serialize by name (graph metadata is JSON).
CREATION_REGISTRY: Dict[str, Callable[..., "CreationFunction"]] = {}


def register_creation_type(name: str):
    """Class decorator: make a creation-function type reconstructible by name."""

    def deco(cls):
        CREATION_REGISTRY[name] = cls
        cls.registry_name = name
        return cls

    return deco


class CreationFunction:
    """Protocol for creation functions ``cr`` (paper §3.1.2).

    ``__call__(parents) -> ModelArtifact`` builds the model from its provenance
    parents. ``initialize(parents)`` optionally builds an *empty* next version
    (used by the update cascade's first phase, Algorithm 2). ``mtl_group``
    (optional str) marks nodes that must be (re)trained together via a merged
    creation function.
    """

    registry_name: str = "base"
    mtl_group: Optional[str] = None

    def __init__(self, **config: Any) -> None:
        self.config = config

    def initialize(self, parents: Sequence["LineageNode"]) -> Optional[ModelArtifact]:
        return None

    def __call__(self, parents: Sequence["LineageNode"]) -> ModelArtifact:
        raise NotImplementedError

    def run_group(self, nodes: Sequence["LineageNode"]) -> List[ModelArtifact]:
        """Merged creation for an MTL group (paper §5): default falls back to
        per-node creation; MTL creation functions override this to share
        parameters / losses across the group."""
        return [node.creation_fn(node.get_parents()) for node in nodes]

    def to_json(self) -> Dict[str, Any]:
        return {"type": self.registry_name, "config": self.config,
                "mtl_group": self.mtl_group}

    @staticmethod
    def from_json(obj: Dict[str, Any]) -> "CreationFunction":
        cls = CREATION_REGISTRY[obj["type"]]
        cr = cls(**obj.get("config", {}))
        cr.mtl_group = obj.get("mtl_group")
        return cr


# ---------------------------------------------------------------------------
# Nodes
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LineageNode:
    name: str
    model_type: str = "generic"
    metadata: Dict[str, Any] = dataclasses.field(default_factory=dict)
    creation_fn: Optional[CreationFunction] = None
    # adjacency (names, not objects — the graph owns the objects)
    parents: List[str] = dataclasses.field(default_factory=list)
    children: List[str] = dataclasses.field(default_factory=list)
    version_parents: List[str] = dataclasses.field(default_factory=list)
    version_children: List[str] = dataclasses.field(default_factory=list)
    # content: either in-memory artifact or a storage ref (manifest id)
    artifact: Optional[ModelArtifact] = dataclasses.field(default=None, repr=False)
    artifact_ref: Optional[str] = None
    _graph: Optional["LineageGraph"] = dataclasses.field(default=None, repr=False)

    def get_model(self) -> ModelArtifact:
        """Materialize the model (loading + decompressing from storage if needed)."""
        if self.artifact is not None:
            return self.artifact
        if self.artifact_ref is not None and self._graph is not None and self._graph.store:
            self.artifact = self._graph.store.load_artifact(self.artifact_ref)
            return self.artifact
        raise ValueError(f"node {self.name!r} has no artifact attached")

    def get_parents(self) -> List["LineageNode"]:
        return [self._graph.nodes[p] for p in self.parents]

    def get_children(self) -> List["LineageNode"]:
        return [self._graph.nodes[c] for c in self.children]

    def to_json(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "model_type": self.model_type,
            "metadata": self.metadata,
            "creation_fn": self.creation_fn.to_json() if self.creation_fn else None,
            "parents": self.parents,
            "children": self.children,
            "version_parents": self.version_parents,
            "version_children": self.version_children,
            "artifact_ref": self.artifact_ref,
        }

    @staticmethod
    def from_json(obj: Dict[str, Any]) -> "LineageNode":
        cr = obj.get("creation_fn")
        return LineageNode(
            name=obj["name"],
            model_type=obj.get("model_type", "generic"),
            metadata=obj.get("metadata", {}),
            creation_fn=CreationFunction.from_json(cr) if cr else None,
            parents=list(obj.get("parents", [])),
            children=list(obj.get("children", [])),
            version_parents=list(obj.get("version_parents", [])),
            version_children=list(obj.get("version_children", [])),
            artifact_ref=obj.get("artifact_ref"),
        )


# ---------------------------------------------------------------------------
# Test functions
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RegisteredTest:
    name: str
    fn: Callable[[ModelArtifact], float]
    node_name: Optional[str] = None    # bound to one model…
    model_type: Optional[str] = None   # …or all models of a type
    # Optional param-key prefix the test exclusively depends on. Declaring a
    # scope lets the diagnostics runner (DESIGN.md §9.3) key memoized results
    # by the scoped parameter content: versions whose scoped submodule is
    # bit-identical share one ledger entry and are never re-tested.
    scope: Optional[str] = None

    def applies_to(self, node: LineageNode) -> bool:
        if self.node_name is not None:
            return node.name == self.node_name
        if self.model_type is not None:
            return node.model_type == self.model_type
        return True


def compile_test_pattern(pattern: Optional[str], match: str = "regex"
                         ) -> Callable[[str], bool]:
    """Build a test-name predicate for ONE explicit matching mode.

    ``match`` is ``"regex"`` (``re.search``), ``"glob"`` (``fnmatch``), or
    ``"legacy"`` — the deprecated regex-OR-glob union that
    ``run_tests(re_pattern=...)`` historically applied (a glob like ``acc*``
    silently matched via fnmatch even when the regex interpretation did
    not). ``pattern=None`` matches everything."""
    if pattern is None:
        return lambda name: True
    if match == "regex":
        rx = re.compile(pattern)
        return lambda name: rx.search(name) is not None
    if match == "glob":
        return lambda name: fnmatch.fnmatch(name, pattern)
    if match == "legacy":
        rx = re.compile(pattern)
        return lambda name: (rx.search(name) is not None
                             or fnmatch.fnmatch(name, pattern))
    raise ValueError(f"unknown pattern match mode {match!r}")


# ---------------------------------------------------------------------------
# The graph
# ---------------------------------------------------------------------------


class LineageGraph:
    """Adjacency-list lineage graph with JSON metadata persistence (paper §3)."""

    def __init__(self, path: Optional[str] = None, store: Any = None,
                 autosave: bool = True) -> None:
        self.path = path
        self.store = store
        self.autosave = autosave and path is not None
        self.nodes: Dict[str, LineageNode] = {}
        self.tests: List[RegisteredTest] = []
        if path is not None and os.path.exists(self._meta_path()):
            self._load()

    # -- persistence ---------------------------------------------------------
    def _meta_path(self) -> str:
        return os.path.join(self.path, "lineage.json")

    def to_payload(self) -> Dict[str, Any]:
        """The graph's JSON document — what ``save`` persists and what the
        remote sync protocol exchanges (``repro.remote``)."""
        return {"nodes": [n.to_json() for n in self.nodes.values()]}

    def save(self) -> None:
        if self.path is None:
            return
        os.makedirs(self.path, exist_ok=True)
        # Atomic AND durable: fsync before the rename, so a crash at any
        # point leaves either the complete old document or the complete new
        # one — never a torn lineage.json (a concurrent pull may read it).
        tmp = self._meta_path() + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_payload(), f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._meta_path())

    def _load(self) -> None:
        with open(self._meta_path()) as f:
            self._install_payload(json.load(f))

    def _install_payload(self, payload: Dict[str, Any]) -> None:
        for obj in payload["nodes"]:
            node = LineageNode.from_json(obj)
            node._graph = self
            self.nodes[node.name] = node

    def replace_nodes(self, payload: Dict[str, Any]) -> None:
        """Swap in a merged document (remote sync): rebuild every node from
        JSON — cached in-memory artifacts are dropped, refs reload lazily
        from the store — and persist."""
        self.nodes = {}
        self._install_payload(payload)
        self._commit()

    def _commit(self) -> None:
        if self.autosave:
            self.save()

    # -- lower-level API (Table 2) --------------------------------------------
    def add_node(self, x: Optional[ModelArtifact], xn: str,
                 cr: Optional[CreationFunction] = None,
                 model_type: Optional[str] = None,
                 persist: bool = True, **metadata: Any) -> LineageNode:
        """Add model ``x`` as node named ``xn``; optionally register ``cr``."""
        if xn in self.nodes:
            node = self.nodes[xn]
            if x is not None:
                if node.model_type == "generic":  # placeholder from add_edge
                    node.model_type = model_type or x.model_type
                self._attach_artifact(node, x, persist=persist)
            if cr is not None:
                node.creation_fn = cr
            self._commit()
            return node
        node = LineageNode(
            name=xn,
            model_type=model_type or (x.model_type if x is not None else "generic"),
            creation_fn=cr,
            metadata=metadata,
        )
        node._graph = self
        self.nodes[xn] = node
        if x is not None:
            self._attach_artifact(node, x, persist=persist)
        self._commit()
        return node

    def _attach_artifact(self, node: LineageNode, artifact: ModelArtifact,
                         persist: bool = True) -> None:
        node.artifact = artifact
        if persist and self.store is not None:
            parent_ref = self._storage_parent_ref(node)
            node.artifact_ref = self.store.commit_artifact(
                node.name, artifact, parent_ref=parent_ref,
                tests=[t for t in self.tests if t.applies_to(node)])

    def _storage_parent_ref(self, node: LineageNode) -> Optional[str]:
        """Pick the storage delta-parent: version parent first, else provenance."""
        for pname in node.version_parents + node.parents:
            p = self.nodes.get(pname)
            if p is not None and p.artifact_ref is not None:
                return p.artifact_ref
        return None

    def _ensure(self, name: str) -> LineageNode:
        if name not in self.nodes:
            self.add_node(None, name)
        return self.nodes[name]

    def add_edge(self, x: str, y: str) -> None:
        """Provenance edge x -> y (y derived from x)."""
        xn, yn = self._ensure(x), self._ensure(y)
        if y not in xn.children:
            xn.children.append(y)
        if x not in yn.parents:
            yn.parents.append(x)
        self._maybe_recompress(yn)
        self._commit()

    def add_version_edge(self, x: str, y: str) -> None:
        """Versioning edge x -> y (y is the next version of x)."""
        xn, yn = self._ensure(x), self._ensure(y)
        if xn.model_type != yn.model_type:
            raise ValueError(
                f"version edge requires same model type: {xn.model_type} != {yn.model_type}")
        if y not in xn.version_children:
            xn.version_children.append(y)
        if x not in yn.version_parents:
            yn.version_parents.append(x)
        self._maybe_recompress(yn)
        self._commit()

    def _maybe_recompress(self, node: LineageNode) -> None:
        """A node committed full *before* its parent edge existed can now be
        delta-compressed against that parent — re-commit (API-order
        robustness: add_node(artifact) then add_edge is as valid as the
        reverse). The superseded full manifest is released and GC'd."""
        if self.store is None or node.artifact_ref is None:
            return
        try:
            manifest = self.store.get_manifest(node.artifact_ref)
        except Exception:
            return
        if manifest.get("depth", 0) > 0:
            return  # already a delta
        parent_ref = self._storage_parent_ref(node)
        if parent_ref is None or parent_ref == node.artifact_ref:
            return
        artifact = node.get_model()
        old_ref = node.artifact_ref
        node.artifact_ref = self.store.commit_artifact(
            node.name, artifact, parent_ref=parent_ref,
            tests=[t for t in self.tests if t.applies_to(node)])
        if node.artifact_ref != old_ref:
            # the cached artifact is a lazy view bound to old_ref — drop it
            # BEFORE releasing, or later accesses resolve against dead objects
            node.artifact = None
            self.store.release(old_ref)
            self.store.gc()

    def remove_edge(self, x: str, y: str, type: str = "provenance") -> None:
        xn, yn = self.nodes[x], self.nodes[y]
        if type == "provenance":
            if y in xn.children:
                xn.children.remove(y)
            if x in yn.parents:
                yn.parents.remove(x)
        elif type == "versioning":
            if y in xn.version_children:
                xn.version_children.remove(y)
            if x in yn.version_parents:
                yn.version_parents.remove(x)
        else:
            raise ValueError(f"unknown edge type {type!r}")
        self._commit()

    def remove_node(self, x: str) -> None:
        """Remove node ``x`` and its (provenance) sub-tree."""
        if x not in self.nodes:
            return
        node = self.nodes[x]
        for child in list(node.children) + list(node.version_children):
            self.remove_node(child)
        for p in list(node.parents):
            self.remove_edge(p, x, "provenance")
        for p in list(node.version_parents):
            self.remove_edge(p, x, "versioning")
        if self.store is not None and node.artifact_ref is not None:
            self.store.release(node.artifact_ref)
        del self.nodes[x]
        self._commit()

    def register_creation_function(self, x: str, cr: CreationFunction) -> None:
        self.nodes[x].creation_fn = cr
        self._commit()

    # -- test functions (Table 2) ---------------------------------------------
    def register_test_function(self, t: Callable[[ModelArtifact], float], tn: str,
                               x: Optional[str] = None,
                               mt: Optional[str] = None,
                               scope: Optional[str] = None) -> None:
        if (x is None) == (mt is None):
            raise ValueError("exactly one of x (node) or mt (model type) must be given")
        self.tests.append(RegisteredTest(name=tn, fn=t, node_name=x,
                                         model_type=mt, scope=scope))

    def deregister_test_function(self, tn: str, x: Optional[str] = None,
                                 mt: Optional[str] = None) -> None:
        self.tests = [
            t for t in self.tests
            if not (t.name == tn and t.node_name == x and t.model_type == mt)
        ]

    def tests_for(self, node: LineageNode) -> List[RegisteredTest]:
        return [t for t in self.tests if t.applies_to(node)]

    # -- queries ---------------------------------------------------------------
    def get_next_version(self, x: str) -> Optional[LineageNode]:
        node = self.nodes[x]
        if node.version_children:
            return self.nodes[node.version_children[0]]
        return None

    def roots(self) -> List[LineageNode]:
        return [n for n in self.nodes.values() if not n.parents]

    def get_model(self, x: str) -> ModelArtifact:
        return self.nodes[x].get_model()

    # -- higher-level API (delegates; see traversal/merge/cascade modules) -----
    def traversal(self, order: str = "bfs", start: Optional[str] = None,
                  edge_types: Sequence[str] = ("provenance",),
                  skip_fn: Optional[Callable[[LineageNode], bool]] = None,
                  terminate_fn: Optional[Callable[[LineageNode], bool]] = None,
                  ) -> Iterator[LineageNode]:
        from repro.core import traversal as trav
        return trav.traverse(self, order=order, start=start, edge_types=edge_types,
                             skip_fn=skip_fn, terminate_fn=terminate_fn)

    def run_tests(self, i: Iterable[LineageNode],
                  re_pattern: Optional[str] = None,
                  pattern: Optional[str] = None,
                  match: str = "regex") -> Dict[str, Dict[str, float]]:
        """Run registered tests whose name matches ``pattern`` on nodes from ``i``.

        ``pattern``/``match`` select ONE explicit matching mode (``"regex"``
        or ``"glob"``). ``re_pattern`` is a deprecated shim that keeps the
        historical regex-OR-glob union behavior; prefer the explicit form.
        This is the eager serial path — the memoized parallel runner lives in
        ``repro.diag.runner`` (DESIGN.md §9.1)."""
        if re_pattern is not None:
            if pattern is not None:
                raise ValueError("pass either re_pattern (deprecated) or "
                                 "pattern=, not both")
            warnings.warn(
                "run_tests(re_pattern=...) matches as regex OR glob; pass "
                "pattern=... with match='regex' or match='glob' instead",
                DeprecationWarning, stacklevel=2)
            pattern, match = re_pattern, "legacy"
        matcher = compile_test_pattern(pattern, match)
        results: Dict[str, Dict[str, float]] = {}
        for node in i:
            node_results: Dict[str, float] = {}
            for t in self.tests_for(node):
                if not matcher(t.name):
                    continue
                node_results[t.name] = float(t.fn(node.get_model()))
            if node_results:
                results[node.name] = node_results
        return results

    def run_function(self, i: Iterable[LineageNode],
                     f: Callable[[ModelArtifact], Any]) -> Dict[str, Any]:
        return {node.name: f(node.get_model()) for node in i}

    def merge(self, x1: str, x2: str, ancestor: Optional[str] = None):
        from repro.core.merge import merge as _merge
        return _merge(self, x1, x2, ancestor=ancestor)

    def run_update_cascade(self, m: str, m_prime: str,
                           skip_fn: Optional[Callable[[LineageNode], bool]] = None,
                           terminate_fn: Optional[Callable[[LineageNode], bool]] = None,
                           gate: Optional[Any] = None) -> List[str]:
        from repro.core.cascade import run_update_cascade as _cascade
        return _cascade(self, m, m_prime, skip_fn=skip_fn,
                        terminate_fn=terminate_fn, gate=gate)

    # -- misc -------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.nodes)

    def __contains__(self, name: str) -> bool:
        return name in self.nodes

    def log(self) -> str:
        """git-log style textual rendering (used by the CLI)."""
        lines = []
        for root in self.roots():
            stack = [(root, 0)]
            seen = set()
            while stack:
                node, depth = stack.pop()
                if node.name in seen:
                    continue
                seen.add(node.name)
                ver = f" [v->{','.join(node.version_children)}]" if node.version_children else ""
                lines.append("  " * depth + f"* {node.name} ({node.model_type}){ver}")
                for c in reversed(node.children):
                    stack.append((self.nodes[c], depth + 1))
        return "\n".join(lines)
