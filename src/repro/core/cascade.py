"""Automated model updating — ``run_update_cascade`` (paper Algorithm 2).

When a model ``m`` is updated to ``m'`` (a new version), every descendant of
``m`` with a registered creation function is rebuilt against the new upstream:

Phase 1 creates (empty) next-version nodes for all descendants, wiring
provenance edges to the *next versions* of their parents (falling back to the
current version when a parent is outside the cascade) and version edges to the
old nodes. Phase 2 walks the new nodes in all-parents-first order and invokes
each node's creation function (or the merged MTL-group creation function) to
materialize the new models. MGit never overwrites the old versions.
"""

from __future__ import annotations

from typing import Callable, List, Optional

from repro.core.lineage import LineageGraph, LineageNode
from repro.core.traversal import all_parents_first, bfs

SkipFn = Optional[Callable[[LineageNode], bool]]
TermFn = Optional[Callable[[LineageNode], bool]]


def next_version_name(name: str) -> str:
    base, sep, suffix = name.rpartition("@v")
    if sep and suffix.isdigit():
        return f"{base}@v{int(suffix) + 1}"
    return f"{name}@v2"


def run_update_cascade(graph: LineageGraph, m: str, m_prime: str,
                       skip_fn: SkipFn = None, terminate_fn: TermFn = None,
                       ) -> List[str]:
    """Trigger the update cascade for the model update ``m -> m_prime``.

    Returns the names of the newly created model versions (excluding m_prime).
    """
    if m_prime not in graph.nodes:
        raise KeyError(f"updated model {m_prime!r} must already be a node")
    if m_prime not in graph.nodes[m].version_children:
        graph.add_version_edge(m, m_prime)

    # ---- Phase 1: create (empty) next versions of all descendants of m. ----
    skip2 = (lambda x: (skip_fn(x) if skip_fn else False) or x.name == m)
    new_names: List[str] = []
    next_of = {m: m_prime}
    for x in bfs(graph, start=m, skip_fn=skip2, terminate_fn=terminate_fn):
        if x.creation_fn is None:
            continue  # nothing to rebuild this node with — leave it untouched
        x_new_name = next_version_name(x.name)
        if x_new_name in graph.nodes:
            continue  # idempotence: cascade already created it
        parents_new = [next_of.get(p, p) for p in x.parents]
        node_new = graph.add_node(None, x_new_name, model_type=x.model_type)
        init = x.creation_fn.initialize([graph.nodes[p] for p in parents_new])
        if init is not None:
            node_new.artifact = init
        for p_new in parents_new:
            graph.add_edge(p_new, x_new_name)
        graph.add_version_edge(x.name, x_new_name)
        node_new.creation_fn = x.creation_fn
        next_of[x.name] = x_new_name
        new_names.append(x_new_name)

    # ---- Phase 2: materialize, all parents first (MTL groups together). ----
    skip3 = (lambda x: (skip_fn(x) if skip_fn else False) or x.name == m_prime)
    for xs in all_parents_first(graph, start=m_prime, skip_fn=skip3,
                                terminate_fn=terminate_fn, group_mtl=True):
        group = xs if isinstance(xs, list) else [xs]
        group = [x for x in group if x.name in new_names]
        if not group:
            continue
        if len(group) > 1:
            # merged MTL creation function: one call produces all group members
            artifacts = group[0].creation_fn.run_group(group)
            for node, artifact in zip(group, artifacts):
                graph._attach_artifact(node, artifact)
        else:
            node = group[0]
            artifact = node.creation_fn(node.get_parents())
            graph._attach_artifact(node, artifact)
    graph._commit()
    return new_names
