"""Automated model updating — ``run_update_cascade`` (paper Algorithm 2).

When a model ``m`` is updated to ``m'`` (a new version), every descendant of
``m`` with a registered creation function is rebuilt against the new upstream:

Phase 1 creates (empty) next-version nodes for all descendants, wiring
provenance edges to the *next versions* of their parents (falling back to the
current version when a parent is outside the cascade) and version edges to the
old nodes. Phase 2 walks the new nodes in all-parents-first order and invokes
each node's creation function (or the merged MTL-group creation function) to
materialize the new models. MGit never overwrites the old versions.

The cascade is exception-safe: a creation function that raises rolls back
every next-version node that was created but never materialized (edges
detached, node deleted, graph re-committed) before the exception propagates —
a failed cascade leaves no half-built empty nodes in the persisted lineage.
Nodes that *did* materialize before the failure are kept; re-running the
cascade is idempotent and picks up where it left off.

Passing ``gate=`` (a :class:`repro.diag.gate.TestGate`, DESIGN.md §9.4) runs
registered tests on each newly materialized version through the memoized
diagnostics runner and *quarantines* regressing nodes: the version edge stays
recorded and the artifact is kept, but the node is marked
``metadata["quarantined"]`` so remote sync excludes it by default.
"""

from __future__ import annotations

from typing import Any, Callable, List, Optional, Set

from repro.core.lineage import LineageGraph, LineageNode
from repro.core.traversal import all_parents_first, bfs

SkipFn = Optional[Callable[[LineageNode], bool]]
TermFn = Optional[Callable[[LineageNode], bool]]


def next_version_name(name: str) -> str:
    base, sep, suffix = name.rpartition("@v")
    if sep and suffix.isdigit():
        return f"{base}@v{int(suffix) + 1}"
    return f"{name}@v2"


def _rollback_unmaterialized(graph: LineageGraph, new_names: List[str],
                             materialized: Set[str]) -> None:
    """Detach and delete cascade nodes that never got a model.

    Reverse creation order, so a child empty node disappears before its
    (possibly also empty) parent. Edges are removed explicitly rather than
    via ``remove_node`` — its subtree recursion would also take down
    already-materialized siblings reachable through shared children."""
    for name in reversed(new_names):
        node = graph.nodes.get(name)
        if node is None or name in materialized:
            continue
        for p in list(node.parents):
            graph.remove_edge(p, name, "provenance")
        for c in list(node.children):
            graph.remove_edge(name, c, "provenance")
        for p in list(node.version_parents):
            graph.remove_edge(p, name, "versioning")
        for c in list(node.version_children):
            graph.remove_edge(name, c, "versioning")
        del graph.nodes[name]
    graph._commit()


def run_update_cascade(graph: LineageGraph, m: str, m_prime: str,
                       skip_fn: SkipFn = None, terminate_fn: TermFn = None,
                       gate: Optional[Any] = None) -> List[str]:
    """Trigger the update cascade for the model update ``m -> m_prime``.

    Returns the names of the newly created model versions (excluding
    m_prime). ``gate`` (anything with ``apply(node) -> decision``) is invoked
    on every newly materialized version; see module docstring.
    """
    if m_prime not in graph.nodes:
        raise KeyError(f"updated model {m_prime!r} must already be a node")
    if m_prime not in graph.nodes[m].version_children:
        graph.add_version_edge(m, m_prime)

    new_names: List[str] = []
    materialized: Set[str] = set()
    try:
        # ---- Phase 1: create (empty) next versions of all descendants. ----
        skip2 = (lambda x: (skip_fn(x) if skip_fn else False) or x.name == m)
        next_of = {m: m_prime}
        for x in bfs(graph, start=m, skip_fn=skip2, terminate_fn=terminate_fn):
            if x.creation_fn is None:
                continue  # nothing to rebuild this node with — leave it untouched
            x_new_name = next_version_name(x.name)
            if x_new_name in graph.nodes:
                # idempotence: cascade already created it — but descendants
                # created THIS run must still rewire to it, so the next_of
                # mapping is recorded before skipping (a resumed cascade
                # otherwise derives children from the stale parent version)
                next_of[x.name] = x_new_name
                continue
            parents_new = [next_of.get(p, p) for p in x.parents]
            node_new = graph.add_node(None, x_new_name, model_type=x.model_type)
            init = x.creation_fn.initialize([graph.nodes[p] for p in parents_new])
            if init is not None:
                node_new.artifact = init
            for p_new in parents_new:
                graph.add_edge(p_new, x_new_name)
            graph.add_version_edge(x.name, x_new_name)
            node_new.creation_fn = x.creation_fn
            next_of[x.name] = x_new_name
            new_names.append(x_new_name)

        # ---- Phase 2: materialize, all parents first (MTL groups together). ----
        skip3 = (lambda x: (skip_fn(x) if skip_fn else False) or x.name == m_prime)
        for xs in all_parents_first(graph, start=m_prime, skip_fn=skip3,
                                    terminate_fn=terminate_fn, group_mtl=True):
            group = xs if isinstance(xs, list) else [xs]
            group = [x for x in group if x.name in new_names]
            if not group:
                continue
            if len(group) > 1:
                # merged MTL creation function: one call produces all group members
                artifacts = group[0].creation_fn.run_group(group)
                for node, artifact in zip(group, artifacts):
                    graph._attach_artifact(node, artifact)
                    materialized.add(node.name)
            else:
                node = group[0]
                artifact = node.creation_fn(node.get_parents())
                graph._attach_artifact(node, artifact)
                materialized.add(node.name)
            if gate is not None:
                for node in group:
                    gate.apply(node)
    except Exception:
        _rollback_unmaterialized(graph, new_names, materialized)
        raise
    graph._commit()
    return new_names
