"""Traversals over the lineage graph (paper §3.1.4).

Traversals are iterators over nodes. They can follow provenance edges,
versioning edges, or both, support skip/terminate predicates, and include the
all-parents-first order used by the update cascade and a binary-search
(bisection) generator for finding the first failing model in a version chain.
"""

from __future__ import annotations

from collections import deque
from typing import Callable, Iterator, List, Optional, Sequence

from repro.core.lineage import LineageGraph, LineageNode

SkipFn = Optional[Callable[[LineageNode], bool]]
TermFn = Optional[Callable[[LineageNode], bool]]


def _children(graph: LineageGraph, node: LineageNode,
              edge_types: Sequence[str]) -> List[LineageNode]:
    out: List[LineageNode] = []
    if "provenance" in edge_types:
        out.extend(graph.nodes[c] for c in node.children)
    if "versioning" in edge_types:
        out.extend(graph.nodes[c] for c in node.version_children)
    return out


def bfs(graph: LineageGraph, start: Optional[str] = None,
        edge_types: Sequence[str] = ("provenance",),
        skip_fn: SkipFn = None, terminate_fn: TermFn = None) -> Iterator[LineageNode]:
    queue = deque(graph.roots() if start is None else [graph.nodes[start]])
    seen = {n.name for n in queue}
    while queue:
        node = queue.popleft()
        if terminate_fn is not None and terminate_fn(node):
            return
        if skip_fn is None or not skip_fn(node):
            yield node
        for child in _children(graph, node, edge_types):
            if child.name not in seen:
                seen.add(child.name)
                queue.append(child)


def dfs(graph: LineageGraph, start: Optional[str] = None,
        edge_types: Sequence[str] = ("provenance",),
        skip_fn: SkipFn = None, terminate_fn: TermFn = None) -> Iterator[LineageNode]:
    stack = list(reversed(graph.roots() if start is None else [graph.nodes[start]]))
    seen = {n.name for n in stack}
    while stack:
        node = stack.pop()
        if terminate_fn is not None and terminate_fn(node):
            return
        if skip_fn is None or not skip_fn(node):
            yield node
        for child in reversed(_children(graph, node, edge_types)):
            if child.name not in seen:
                seen.add(child.name)
                stack.append(child)


def version_chain(graph: LineageGraph, start: str) -> Iterator[LineageNode]:
    """All versions of a model, oldest -> newest, following version edges only."""
    node: Optional[LineageNode] = graph.nodes[start]
    # rewind to the first version
    while node.version_parents:
        node = graph.nodes[node.version_parents[0]]
    while node is not None:
        yield node
        node = graph.nodes[node.version_children[0]] if node.version_children else None


def all_parents_first(graph: LineageGraph, start: Optional[str] = None,
                      skip_fn: SkipFn = None, terminate_fn: TermFn = None,
                      group_mtl: bool = False) -> Iterator[object]:
    """Kahn-style order: a node is yielded only once ALL its provenance parents
    (within the traversed region) have been yielded. Used by Algorithm 2.

    With ``group_mtl=True``, nodes whose creation functions share an
    ``mtl_group`` are yielded together as a list once the whole group is ready.
    """
    if start is None:
        region = {n.name for n in graph.nodes.values()}
        frontier = deque(graph.roots())
    else:
        root = graph.nodes[start]
        region = {root.name}
        q = deque([root])
        while q:
            n = q.popleft()
            for c in n.children:
                if c not in region:
                    region.add(c)
                    q.append(graph.nodes[c])
        frontier = deque([root])

    visited: set = set()
    emitted: set = set()
    queue = frontier
    pending: List[LineageNode] = []

    def ready(node: LineageNode) -> bool:
        return all(p not in region or p in visited for p in node.parents)

    while queue or pending:
        made_progress = False
        requeue: List[LineageNode] = []
        for node in list(queue) + pending:
            if node.name in visited:
                continue
            if not ready(node):
                requeue.append(node)
                continue
            visited.add(node.name)
            made_progress = True
            if terminate_fn is not None and terminate_fn(node):
                return
            if skip_fn is None or not skip_fn(node):
                if group_mtl and node.creation_fn is not None and node.creation_fn.mtl_group:
                    grp = node.creation_fn.mtl_group
                    members = [
                        graph.nodes[n] for n in region
                        if graph.nodes[n].creation_fn is not None
                        and graph.nodes[n].creation_fn.mtl_group == grp
                    ]
                    if all(m.name in visited or ready(m) for m in members):
                        group = [m for m in members if m.name not in emitted]
                        for m in group:
                            visited.add(m.name)
                            emitted.add(m.name)
                        if group:
                            yield group
                    else:
                        visited.discard(node.name)
                        requeue.append(node)
                        continue
                else:
                    emitted.add(node.name)
                    yield node
            for c in node.children:
                if c in region and c not in visited:
                    requeue.append(graph.nodes[c])
        queue = deque()
        pending = [n for n in requeue if n.name not in visited]
        if not made_progress and pending:
            # cycle or unreachable parents — bail out rather than spin
            return


def bisect(graph: LineageGraph, start: str,
           failing: Callable[[LineageNode], bool],
           skip_fn: SkipFn = None) -> Optional[LineageNode]:
    """Binary search over a version chain for the FIRST failing version.

    Assumes monotonicity (once a version fails, later versions fail) — the
    standard git-bisect contract. Returns None if no version fails.
    ``skip_fn`` marks versions that cannot be probed (git-bisect-skip):
    they are excluded from the search entirely, so the result is the first
    failing *probe-able* version. DAG-wide attribution (classifying a
    failure as introduced / inherited / merge-emergent rather than finding
    one chain position) lives in ``repro.diag.blame`` (DESIGN.md §9.2).
    """
    chain = [n for n in version_chain(graph, start)
             if skip_fn is None or not skip_fn(n)]
    lo, hi = 0, len(chain) - 1
    if not chain or not failing(chain[hi]):
        return None
    if failing(chain[0]):
        return chain[0]
    # invariant: chain[lo] passes, chain[hi] fails
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if failing(chain[mid]):
            hi = mid
        else:
            lo = mid
    return chain[hi]


def traverse(graph: LineageGraph, order: str = "bfs", **kwargs) -> Iterator[object]:
    if order == "bfs":
        return bfs(graph, **kwargs)
    if order == "dfs":
        return dfs(graph, **kwargs)
    if order == "versions":
        return version_chain(graph, kwargs["start"])
    if order == "all_parents_first":
        kwargs.pop("edge_types", None)
        return all_parents_first(graph, **kwargs)
    raise ValueError(f"unknown traversal order {order!r}")
