"""MGit core: lineage graph, diff, merge, update cascade, auto-construction."""

from repro.core.artifact import ModelArtifact, param_key, split_key
from repro.core.auto import auto_construct, auto_insert, choose_parent
from repro.core.cascade import next_version_name, run_update_cascade
from repro.core.diff import DiffResult, divergence_scores, module_diff
from repro.core.graphir import LayerGraph, LayerNode
from repro.core.lineage import (CreationFunction, LineageGraph, LineageNode,
                                RegisteredTest, register_creation_type)
from repro.core.merge import (CONFLICT, NO_CONFLICT, POSSIBLE_CONFLICT,
                              MergeResult, merge, merge_artifacts)
from repro.core.quarantine import (QUARANTINE_FLAG, QUARANTINE_RECORD,
                                   is_quarantined)
from repro.core.traversal import (all_parents_first, bfs, bisect, dfs,
                                  version_chain)

__all__ = [
    "ModelArtifact", "param_key", "split_key",
    "auto_construct", "auto_insert", "choose_parent",
    "next_version_name", "run_update_cascade",
    "DiffResult", "divergence_scores", "module_diff",
    "LayerGraph", "LayerNode",
    "CreationFunction", "LineageGraph", "LineageNode", "RegisteredTest",
    "register_creation_type",
    "CONFLICT", "NO_CONFLICT", "POSSIBLE_CONFLICT", "MergeResult", "merge",
    "merge_artifacts",
    "QUARANTINE_FLAG", "QUARANTINE_RECORD", "is_quarantined",
    "all_parents_first", "bfs", "bisect", "dfs", "version_chain",
]
