"""ModelArtifact — the unit MGit versions: a LayerGraph plus its parameters.

Parameters are a flat mapping ``"layer/param" -> ndarray``. Artifacts are what
creation functions return, what ``diff``/``merge`` compare, and what the storage
layer persists (via the CAS + delta compression).

Artifacts loaded from storage are *lazy* (DESIGN.md §3.4): ``params`` is a
:class:`LazyParams` mapping whose values are :class:`ParamRef` handles that
materialize per-tensor through the store's chain resolver on first access.
Shape/dtype/content-hash metadata comes from the manifest, so ``nbytes``,
``param_hashes`` (and therefore contextual ``diff``) never touch tensor data.
"""

from __future__ import annotations

import dataclasses
from collections.abc import MutableMapping
from typing import Any, Callable, Dict, Iterator, Mapping, Optional, Tuple

import numpy as np

from repro.common.hashing import tensor_hash
from repro.core.graphir import LayerGraph


def param_key(layer: str, param: str) -> str:
    return f"{layer}/{param}"


def split_key(key: str):
    layer, _, param = key.rpartition("/")
    return layer, param


# ---------------------------------------------------------------------------
# Lazy parameter views
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamRef:
    """Handle to one stored parameter: metadata now, tensor on demand.

    ``store`` is any object with ``materialize_param(ref, key) -> ndarray``
    (duck-typed so ``core`` does not import ``store``)."""

    store: Any = dataclasses.field(repr=False)
    ref: str                      # manifest ref the parameter lives in
    key: str                      # flat "layer/param" key
    shape: Tuple[int, ...]
    dtype: str
    hash: Optional[str] = None    # content hash recorded at commit time

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64) *
                   np.dtype(self.dtype).itemsize) if self.shape else \
            np.dtype(self.dtype).itemsize

    def materialize(self) -> np.ndarray:
        return self.store.materialize_param(self.ref, self.key)


class LazyParams(MutableMapping):
    """Flat param mapping that materializes tensors per-key on access.

    Backed by ``ParamRef`` handles; assigning a value (``p[k] = arr``) installs
    an eager override, which is how functional updates (``replace_params``,
    merge) stay lazy for every parameter they did not touch."""

    def __init__(self, refs: Dict[str, ParamRef],
                 overrides: Optional[Dict[str, np.ndarray]] = None) -> None:
        self._refs = dict(refs)
        self._overrides: Dict[str, np.ndarray] = dict(overrides or {})

    # -- mapping protocol -----------------------------------------------------
    def __getitem__(self, key: str) -> np.ndarray:
        if key in self._overrides:
            return self._overrides[key]
        return self._refs[key].materialize()

    def __setitem__(self, key: str, value) -> None:
        self._overrides[key] = value

    def __delitem__(self, key: str) -> None:
        found = key in self._overrides or key in self._refs
        self._overrides.pop(key, None)
        self._refs.pop(key, None)
        if not found:
            raise KeyError(key)

    def __contains__(self, key: object) -> bool:
        # MutableMapping's default __contains__ calls __getitem__, which
        # MATERIALIZES the tensor — membership must stay metadata-only
        return key in self._refs or key in self._overrides

    def __iter__(self) -> Iterator[str]:
        for k in self._refs:
            yield k
        for k in self._overrides:
            if k not in self._refs:
                yield k

    def __len__(self) -> int:
        return len(set(self._refs) | set(self._overrides))

    def __repr__(self) -> str:
        return (f"LazyParams({len(self)} params, "
                f"{len(self._overrides)} overridden)")

    # -- metadata without materialization --------------------------------------
    def ref_of(self, key: str) -> Optional[ParamRef]:
        if key in self._overrides:
            return None
        return self._refs.get(key)

    def spec_of(self, key: str) -> Tuple[Tuple[int, ...], str]:
        """(shape, dtype) without touching tensor data."""
        if key in self._overrides:
            v = self._overrides[key]
            return tuple(np.shape(v)), str(np.asarray(v).dtype)
        r = self._refs[key]
        return tuple(r.shape), r.dtype

    def hash_of(self, key: str) -> Optional[str]:
        """Commit-time content hash, or None for overridden/unhashed keys."""
        if key in self._overrides:
            return None
        r = self._refs.get(key)
        return r.hash if r is not None else None

    def nbytes_total(self) -> int:
        total = 0
        for k in self:
            if k in self._overrides:
                total += int(np.asarray(self._overrides[k]).nbytes)
            else:
                total += self._refs[k].nbytes
        return total

    def with_overrides(self, updates: Mapping[str, np.ndarray]) -> "LazyParams":
        merged = dict(self._overrides)
        merged.update(updates)
        return LazyParams(self._refs, merged)


@dataclasses.dataclass
class ModelArtifact:
    """A model = structure (LayerGraph) + content (flat param dict) + metadata."""

    graph: LayerGraph
    params: Dict[str, np.ndarray]
    model_type: str = "generic"
    metadata: Dict[str, Any] = dataclasses.field(default_factory=dict)
    _hashes: Optional[Dict[str, str]] = dataclasses.field(default=None, repr=False)

    def param_hashes(self, recompute: bool = False) -> Dict[str, str]:
        """Content hash per parameter; cached (params are treated as immutable).

        Lazy artifacts answer from manifest metadata: only parameters without
        a recorded hash (e.g. overridden ones) are materialized."""
        if self._hashes is None or recompute:
            if isinstance(self.params, LazyParams) and not recompute:
                self._hashes = {
                    k: self.params.hash_of(k) or tensor_hash(self.params[k])
                    for k in self.params
                }
            else:
                self._hashes = {k: tensor_hash(v)
                                for k, v in self.params.items()}
            # Attach to the LayerGraph so contextual diff sees them.
            per_layer: Dict[str, Dict[str, str]] = {}
            for key, h in self._hashes.items():
                layer, param = split_key(key)
                per_layer.setdefault(layer, {})[param] = h
            self.graph.set_param_hashes(per_layer)
        return self._hashes

    @property
    def is_lazy(self) -> bool:
        return isinstance(self.params, LazyParams)

    def nbytes(self) -> int:
        if isinstance(self.params, LazyParams):
            return self.params.nbytes_total()
        total = 0
        for v in self.params.values():
            # trust an integer ``nbytes`` attribute (ndarrays and chunk
            # sources both carry one) — np.asarray on a streaming chunk
            # source would try to materialize a multi-GB tensor
            n = getattr(v, "nbytes", None)
            total += (int(n) if isinstance(n, (int, np.integer))
                      else int(np.asarray(v).nbytes))
        return total

    def _clone_graph(self) -> LayerGraph:
        """Structure-preserving copy. Artifacts must not share LayerGraph objects:
        contextual hashes are attached to graph nodes, so a shared graph would let
        one artifact clobber another's content fingerprints."""
        g = LayerGraph.from_json(self.graph.to_json())
        for node in g.nodes.values():
            node.param_hashes = {}
        return g

    def replace_params(self, new_params: Mapping[str, np.ndarray],
                       **metadata: Any) -> "ModelArtifact":
        """Functional update: same structure (cloned), new parameter values.

        On a lazy artifact the untouched parameters stay lazy (the update
        installs overrides instead of materializing the whole model)."""
        if isinstance(self.params, LazyParams):
            merged: Any = self.params.with_overrides(new_params)
        else:
            merged = dict(self.params)
            merged.update(new_params)
        meta = dict(self.metadata)
        meta.update(metadata)
        return ModelArtifact(graph=self._clone_graph(), params=merged,
                             model_type=self.model_type, metadata=meta)

    def map_params(self, fn: Callable[[str, np.ndarray], np.ndarray]) -> "ModelArtifact":
        return ModelArtifact(
            graph=self._clone_graph(),
            params={k: fn(k, v) for k, v in self.params.items()},
            model_type=self.model_type,
            metadata=dict(self.metadata),
        )

    def __repr__(self) -> str:
        mb = self.nbytes() / 1e6
        return (f"ModelArtifact(type={self.model_type!r}, layers={len(self.graph)}, "
                f"params={len(self.params)}, {mb:.1f}MB)")
