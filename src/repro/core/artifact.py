"""ModelArtifact — the unit MGit versions: a LayerGraph plus its parameters.

Parameters are a flat mapping ``"layer/param" -> ndarray``. Artifacts are what
creation functions return, what ``diff``/``merge`` compare, and what the storage
layer persists (via the CAS + delta compression).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, Optional

import numpy as np

from repro.common.hashing import tensor_hash
from repro.core.graphir import LayerGraph


def param_key(layer: str, param: str) -> str:
    return f"{layer}/{param}"


def split_key(key: str):
    layer, _, param = key.rpartition("/")
    return layer, param


@dataclasses.dataclass
class ModelArtifact:
    """A model = structure (LayerGraph) + content (flat param dict) + metadata."""

    graph: LayerGraph
    params: Dict[str, np.ndarray]
    model_type: str = "generic"
    metadata: Dict[str, Any] = dataclasses.field(default_factory=dict)
    _hashes: Optional[Dict[str, str]] = dataclasses.field(default=None, repr=False)

    def param_hashes(self, recompute: bool = False) -> Dict[str, str]:
        """Content hash per parameter; cached (params are treated as immutable)."""
        if self._hashes is None or recompute:
            self._hashes = {k: tensor_hash(v) for k, v in self.params.items()}
            # Attach to the LayerGraph so contextual diff sees them.
            per_layer: Dict[str, Dict[str, str]] = {}
            for key, h in self._hashes.items():
                layer, param = split_key(key)
                per_layer.setdefault(layer, {})[param] = h
            self.graph.set_param_hashes(per_layer)
        return self._hashes

    def nbytes(self) -> int:
        return int(sum(np.asarray(v).nbytes for v in self.params.values()))

    def _clone_graph(self) -> LayerGraph:
        """Structure-preserving copy. Artifacts must not share LayerGraph objects:
        contextual hashes are attached to graph nodes, so a shared graph would let
        one artifact clobber another's content fingerprints."""
        g = LayerGraph.from_json(self.graph.to_json())
        for node in g.nodes.values():
            node.param_hashes = {}
        return g

    def replace_params(self, new_params: Mapping[str, np.ndarray],
                       **metadata: Any) -> "ModelArtifact":
        """Functional update: same structure (cloned), new parameter values."""
        merged = dict(self.params)
        merged.update(new_params)
        meta = dict(self.metadata)
        meta.update(metadata)
        return ModelArtifact(graph=self._clone_graph(), params=merged,
                             model_type=self.model_type, metadata=meta)

    def map_params(self, fn: Callable[[str, np.ndarray], np.ndarray]) -> "ModelArtifact":
        return ModelArtifact(
            graph=self._clone_graph(),
            params={k: fn(k, v) for k, v in self.params.items()},
            model_type=self.model_type,
            metadata=dict(self.metadata),
        )

    def __repr__(self) -> str:
        mb = self.nbytes() / 1e6
        return (f"ModelArtifact(type={self.model_type!r}, layers={len(self.graph)}, "
                f"params={len(self.params)}, {mb:.1f}MB)")
