"""The ``merge`` primitive (paper §5, Figure 2).

Given two models independently derived from a common ancestor, classify the
concurrent changes as:

* ``conflict``          — both users changed at least one common layer -> manual merge;
* ``possible_conflict`` — the changed layer sets are disjoint but *dependent*
                          (one eventually consumes the other's output, or a
                          downstream layer consumes both) -> run tests to verify;
* ``no_conflict``       — disjoint and independent -> auto-merge.

Change detection is powered by ``diff``: structural matching maps layers
between ancestor and each derivative; a matched layer counts as changed when
its parameter content hash differs; unmatched layers are structural edits.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from repro.core.artifact import ModelArtifact
from repro.core.diff import module_diff
from repro.core.lineage import LineageGraph

CONFLICT = "conflict"
POSSIBLE_CONFLICT = "possible_conflict"
NO_CONFLICT = "no_conflict"


@dataclasses.dataclass
class ChangeSet:
    """Changes of one derivative relative to the ancestor, in ancestor namespace."""

    changed: Set[str]          # matched layers whose parameters differ
    removed: Set[str]          # ancestor layers with no structural match
    added: Set[str]            # new layer names (derivative namespace)
    match_map: Dict[str, str]  # ancestor layer -> derivative layer

    @property
    def touched(self) -> Set[str]:
        return self.changed | self.removed


def compute_changeset(ancestor: ModelArtifact, derived: ModelArtifact) -> ChangeSet:
    ancestor.param_hashes()
    derived.param_hashes()
    d = module_diff(ancestor, derived, mode="structural")
    mm = d.match_map()
    changed: Set[str] = set()
    for a_name, b_name in mm.items():
        ah = ancestor.graph.nodes[a_name].contextual_hash()
        bh = derived.graph.nodes[b_name].contextual_hash()
        if ah != bh:
            changed.add(a_name)
    return ChangeSet(changed=changed, removed=set(d.del_nodes),
                     added=set(d.add_nodes), match_map=mm)


def _dependent(graph, c1: Set[str], c2: Set[str]) -> bool:
    """True if any changed layer pair is dependent (paper's DFS check):
    one reaches the other, or some layer is reachable from both."""
    if not c1 or not c2:
        return False
    r1 = graph.reachable_from(c1) | c1
    r2 = graph.reachable_from(c2) | c2
    # one consumes the other's output (directly or eventually)
    if (graph.reachable_from(c1) & c2) or (graph.reachable_from(c2) & c1):
        return True
    # a downstream layer consumes outputs of both
    return bool((r1 & r2) - (c1 | c2) - ((c1 & r2) | (c2 & r1)))


@dataclasses.dataclass
class MergeResult:
    status: str
    merged: Optional[ModelArtifact]
    conflicting_layers: List[str]
    test_results: Dict[str, float]
    detail: str = ""


def merge_artifacts(ancestor: ModelArtifact, m1: ModelArtifact, m2: ModelArtifact,
                    tests: Optional[list] = None,
                    test_threshold: float = 0.0) -> MergeResult:
    """Three-way merge of artifacts per the Figure 2 decision tree."""
    cs1 = compute_changeset(ancestor, m1)
    cs2 = compute_changeset(ancestor, m2)

    overlap = sorted(cs1.touched & cs2.touched)
    if cs1.added and cs2.added and (cs1.added & cs2.added):
        overlap = sorted(set(overlap) | (cs1.added & cs2.added))
    if overlap:
        return MergeResult(CONFLICT, None, overlap, {},
                           detail="common layer(s) updated by both changes")

    merged = _apply_changes(ancestor, m1, cs1)
    merged = _apply_changes(merged, m2, cs2)

    if _dependent(ancestor.graph, cs1.touched, cs2.touched):
        results: Dict[str, float] = {}
        if tests:
            for t in tests:
                results[t.name] = float(t.fn(merged))
            ok = all(v >= test_threshold for v in results.values())
            status = NO_CONFLICT if ok else CONFLICT
            detail = ("dependent changes; tests "
                      + ("passed" if ok else "FAILED"))
            return MergeResult(status, merged if ok else None,
                               [] if ok else sorted(cs1.touched | cs2.touched),
                               results, detail)
        return MergeResult(POSSIBLE_CONFLICT, merged, [], {},
                           detail="dependent changes; no tests registered — verify manually")

    return MergeResult(NO_CONFLICT, merged, [], {}, detail="independent changes")


def _apply_changes(base: ModelArtifact, derived: ModelArtifact,
                   cs: ChangeSet) -> ModelArtifact:
    """Apply one derivative's parameter changes onto ``base`` (ancestor-shaped).

    Structural edits (add/remove layers) are applied only when they do not
    collide with the other side — callers guarantee disjointness by this point.
    """
    new_params = {}
    for a_layer in cs.changed:
        b_layer = cs.match_map[a_layer]
        for pname in derived.graph.nodes[b_layer].params:
            key_b = f"{b_layer}/{pname}"
            key_a = f"{a_layer}/{pname}"
            if key_b in derived.params:
                new_params[key_a] = derived.params[key_b]
    out = base.replace_params(new_params)
    # Structural adds/removes: rebuild graph if needed.
    if cs.added or cs.removed:
        from repro.core.graphir import LayerGraph
        g = LayerGraph()
        keep = [n for n in base.graph.nodes if n not in cs.removed]
        for n in keep:
            g.add_node(base.graph.nodes[n])
        inv = {v: k for k, v in cs.match_map.items()}
        for n in cs.added:
            g.add_node(derived.graph.nodes[n])
            for key in list(derived.params):
                if key.startswith(n + "/"):
                    out.params[key] = derived.params[key]
        for (s, d) in base.graph.edges:
            if s in g.nodes and d in g.nodes:
                g.add_edge(s, d)
        for (s, d) in derived.graph.edges:
            s2, d2 = inv.get(s, s), inv.get(d, d)
            if (s in cs.added or d in cs.added) and s2 in g.nodes and d2 in g.nodes:
                g.add_edge(s2, d2)
        out = ModelArtifact(graph=g, params=out.params,
                            model_type=out.model_type, metadata=out.metadata)
    return out


def _common_ancestor(graph: LineageGraph, x1: str, x2: str) -> Optional[str]:
    """Closest common ancestor over provenance+versioning edges (min total hops)."""

    def ancestors(name: str) -> Dict[str, int]:
        dist = {name: 0}
        frontier = [name]
        while frontier:
            nxt = []
            for n in frontier:
                node = graph.nodes[n]
                for p in node.parents + node.version_parents:
                    if p not in dist:
                        dist[p] = dist[n] + 1
                        nxt.append(p)
            frontier = nxt
        return dist

    a1, a2 = ancestors(x1), ancestors(x2)
    common = set(a1) & set(a2) - {x1, x2}
    if not common:
        return None
    return min(common, key=lambda n: (a1[n] + a2[n], n))


def merge(graph: LineageGraph, x1: str, x2: str,
          ancestor: Optional[str] = None, test_threshold: float = 0.0) -> MergeResult:
    """Graph-level merge: resolve the common ancestor, merge artifacts, and on
    success insert the merged model as a new node with provenance edges."""
    anc = ancestor or _common_ancestor(graph, x1, x2)
    if anc is None:
        return MergeResult(CONFLICT, None, [], {},
                           detail="no common ancestor in lineage graph")
    n1, n2 = graph.nodes[x1], graph.nodes[x2]
    tests = [t for t in graph.tests if t.applies_to(n1) or t.applies_to(n2)]
    result = merge_artifacts(graph.get_model(anc), n1.get_model(), n2.get_model(),
                             tests=tests, test_threshold=test_threshold)
    if result.merged is not None and result.status != CONFLICT:
        merged_name = f"merge({x1},{x2})"
        graph.add_node(result.merged, merged_name,
                       model_type=graph.nodes[x1].model_type)
        graph.add_edge(x1, merged_name)
        graph.add_edge(x2, merged_name)
    return result
