"""AdamW with ZeRO-style sharded states and optional distributed tricks.

Optimizer moments are stored in fp32 and inherit the parameter shardings
(which are already FSDP+TP sharded over ('data','model') — i.e. fully
sharded optimizer state, ZeRO-3 layout). Parameters live in the model compute
dtype (bf16 by default); the update runs in fp32 and casts back. A optional
fp32 master copy can be enabled for small models.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


class OptState(NamedTuple):
    mu: Any
    nu: Any
    count: jnp.ndarray


def init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(mu=jax.tree_util.tree_map(zeros, params),
                    nu=jax.tree_util.tree_map(zeros, params),
                    count=jnp.zeros((), jnp.int32))


def state_regime(key: str) -> str:
    """Storage regime of one flattened train-state leaf (DESIGN.md §15).

    The step-delta checkpoint engine picks codecs per optimizer regime:
    ``moment2`` (AdamW nu — smooth, nonnegative, slowly varying in *relative*
    terms) is stored in the log domain so uniform quantization gives relative
    precision; ``moment1`` (mu) and ``params`` take the standard sparse-
    delta path. Keys follow ``flatten_state``'s layout: ``opt/mu/...``,
    ``opt/nu/...``, ``params/...``."""
    if key.startswith("opt/nu/"):
        return "moment2"
    if key.startswith("opt/mu/"):
        return "moment1"
    return "params"


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def update(cfg: AdamWConfig, grads, state: OptState, params):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    count = state.count + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0
    lr = schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        step = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(new_m, new_v, count), metrics
