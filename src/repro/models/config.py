"""Model configuration + registry. One ``configs/<arch>.py`` per assigned arch."""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional


@dataclasses.dataclass
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int = 0                # 0 for attention-free (ssm)
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 32000
    head_dim: int = 0               # 0 -> d_model // n_heads
    mlp_type: str = "swiglu"        # swiglu | gelu
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    window: int = 0                 # sliding-window attention if > 0
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    # --- hybrid (jamba): one attention layer per `attn_period` layers, MoE on
    # every `moe_period`-th layer ---
    attn_period: int = 0
    attn_offset: int = 4
    moe_period: int = 0
    # --- enc-dec / frontends ---
    n_encoder_layers: int = 0
    frontend: str = "none"          # none | vision_stub | audio_stub
    n_prefix_tokens: int = 0        # vision patches fed as embeddings
    # --- numerics / compile ---
    dtype: str = "bfloat16"
    remat: str = "dots"             # none | dots | full
    attn_chunk: int = 1024          # KV block for memory-efficient attention
    # --- technique applicability (DESIGN.md §Arch-applicability) ---
    subquadratic: bool = False      # True -> long_500k decode supported

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def is_attention_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.family == "hybrid":
            return self.attn_period > 0 and i % self.attn_period == self.attn_offset
        return True

    def is_moe_layer(self, i: int) -> bool:
        if self.n_experts == 0:
            return False
        if self.family == "hybrid":
            return self.moe_period > 0 and i % self.moe_period == self.moe_period - 1
        return True

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        small = dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 4 if self.family != "hybrid" else self.attn_period),
            d_model=128,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            head_dim=32 if self.n_heads else 0,
            n_experts=min(self.n_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            # no-drop capacity so decode (tiny T) matches full forward exactly
            capacity_factor=float(min(self.n_experts, 4) or 1),
            ssm_state=min(self.ssm_state, 32) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            ssm_chunk=32,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            n_prefix_tokens=min(self.n_prefix_tokens, 8),
            window=min(self.window, 64) if self.window else 0,
            attn_chunk=64,
            dtype="float32",
        )
        if self.family == "hybrid":
            small = dataclasses.replace(small, attn_period=4, attn_offset=2,
                                        moe_period=2, n_layers=4)
        return dataclasses.replace(small, **overrides)


# -- registry -----------------------------------------------------------------
_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register_arch(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        import repro.configs  # noqa: F401  (populates the registry)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> list:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
