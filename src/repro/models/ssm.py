"""Mamba2 / SSD (state-space duality) layer — chunked scan + O(1) decode.

Train/prefill use the SSD chunked algorithm (quadratic attention-like math
inside fixed-size chunks, linear recurrence across chunks), which maps onto
the MXU as batched matmuls. Decode keeps a constant-size (H, P, N) state per
layer — this is why the SSM/hybrid architectures are the ones that run the
``long_500k`` cells (DESIGN.md §5).

Parameter layout per layer (stacked over L in the model):
  in_proj: (D, 2*d_inner + 2*G*N + H)   [z | x | B | C | dt]
  conv_w : (K, d_inner + 2*G*N)         depthwise causal conv
  A_log, dt_bias, D: (H,)
  norm   : (d_inner,)  gated RMSNorm before out_proj
  out_proj: (d_inner, D)
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models.config import ModelConfig

G = 1  # B/C groups (mamba2 default: single group broadcast over heads)


def _split_proj(cfg: ModelConfig, zxbcdt: jnp.ndarray):
    d_in = cfg.d_inner
    N = cfg.ssm_state
    H = cfg.n_ssm_heads
    z, xs, B, C, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + G * N, 2 * d_in + 2 * G * N], axis=-1)
    return z, xs, B, C, dt


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 cache: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. x: (B,S,Cd), w: (K,Cd). Returns (y, new_cache)."""
    K = w.shape[0]
    if cache is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = cache
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, Cd)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_cache = xp[:, -(K - 1):] if K > 1 else pad
    return y, new_cache


def ssd_chunked(xh: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
                init_state: Optional[jnp.ndarray] = None):
    """SSD over chunks. xh: (B,S,H,P); dt: (B,S,H); A: (H,) (negative);
    Bm, Cm: (B,S,N) (group broadcast over heads). Returns (y, final_state)."""
    Bb, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    while S % Q:  # largest divisor <= requested chunk (exact tiling)
        Q -= 1
    nc = S // Q

    xd = (xh * dt[..., None]).reshape(Bb, nc, Q, H, P)
    dA = (dt * A).reshape(Bb, nc, Q, H)                     # (B,nc,Q,H) ≤ 0
    cs = jnp.cumsum(dA, axis=2)                             # within-chunk cumsum
    Bc = Bm.reshape(Bb, nc, Q, N)
    Cc = Cm.reshape(Bb, nc, Q, N)

    # intra-chunk (quadratic in Q): L[i,j] = exp(cs_i - cs_j) for i >= j
    rel = cs[:, :, :, None, :] - cs[:, :, None, :, :]       # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    rel = jnp.where(mask[None, None, :, :, None], rel, -1e30)  # mask pre-exp
    L = jnp.exp(rel)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)          # (B,nc,Q,Q)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, L, xd)

    # chunk-final states: S_c = sum_j exp(cs_Q - cs_j) B_j x_j^T
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)           # (B,nc,Q,H)
    S_c = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", decay_to_end, Bc, xd)

    # inter-chunk linear recurrence over nc
    chunk_decay = jnp.exp(cs[:, :, -1, :])                  # (B,nc,H)

    def step(state, inp):
        S_c_t, decay_t = inp                                # (B,H,N,P), (B,H)
        out_state = state                                   # state BEFORE chunk
        state = state * decay_t[..., None, None] + S_c_t
        return state, out_state

    init = (jnp.zeros((Bb, H, N, P), jnp.float32) if init_state is None
            else init_state.astype(jnp.float32))
    final_state, prev_states = jax.lax.scan(
        step, init,
        (S_c.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2).astype(jnp.float32)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)      # (B,nc,H,N,P)

    # inter-chunk contribution: C_i · (decay_i * state_prev)
    decay_in = jnp.exp(cs)                                   # (B,nc,Q,H)
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp", Cc, decay_in,
                         prev_states.astype(Cc.dtype))
    y = (y_intra + y_inter).reshape(Bb, S, H, P)
    return y, final_state


def ssm_layer(x: jnp.ndarray, p: Dict, cfg: ModelConfig,
              cache: Optional[Dict] = None) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Full Mamba2 block. cache={"state": (B,H,N,P), "conv": (B,K-1,Cd)}."""
    Bb, S, D = x.shape
    H, P, N = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state

    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"])
    z, xs, Bm, Cm, dt = _split_proj(cfg, zxbcdt)

    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"],
                                      cache["conv"] if cache else None)
    conv_out = jax.nn.silu(conv_out)
    xs = conv_out[..., :cfg.d_inner]
    Bm = conv_out[..., cfg.d_inner:cfg.d_inner + G * N]
    Cm = conv_out[..., cfg.d_inner + G * N:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                  # (H,)
    xh = xs.reshape(Bb, S, H, P)
    xh = shard(xh, ("pod", "data"), None, "model", None)

    if cache is None:
        y, _ = ssd_chunked(xh.astype(jnp.float32), dt, A,
                           Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                           cfg.ssm_chunk)
        new_cache = None
    elif S > 1:
        # prefill: chunked SSD over the whole prompt (NOT the recurrent
        # per-token scan — that is O(S) sequential full-state round-trips,
        # measured as a ~2000s memory term on jamba prefill; §Perf-A),
        # carrying the state in/out of the cache.
        y, final_state = ssd_chunked(xh.astype(jnp.float32), dt, A,
                                     Bm.astype(jnp.float32),
                                     Cm.astype(jnp.float32), cfg.ssm_chunk,
                                     init_state=cache["state"])
        new_cache = {"state": final_state, "conv": new_conv}
    else:
        # O(1) recurrent decode (S is 1, or small): per-step state update
        def step(state, inp):
            xh_t, dt_t, B_t, C_t = inp
            dA = jnp.exp(dt_t * A)                                # (B,H)
            dBx = jnp.einsum("bh,bn,bhp->bhnp", dt_t, B_t, xh_t)
            state = state * dA[..., None, None] + dBx
            y_t = jnp.einsum("bn,bhnp->bhp", C_t, state)
            return state, y_t

        state = cache["state"].astype(jnp.float32)
        state, ys = jax.lax.scan(
            step, state,
            (xh.transpose(1, 0, 2, 3).astype(jnp.float32),
             dt.transpose(1, 0, 2),
             Bm.transpose(1, 0, 2).astype(jnp.float32),
             Cm.transpose(1, 0, 2).astype(jnp.float32)))
        y = ys.transpose(1, 0, 2, 3)                              # (B,S,H,P)
        new_cache = {"state": state, "conv": new_conv}

    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(Bb, S, cfg.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    # gated RMSNorm (mamba2 places a norm before out_proj)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)
         * (1.0 + p["norm"])).astype(x.dtype)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    return shard(out, ("pod", "data"), None, None), new_cache
