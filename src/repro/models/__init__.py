"""Composable model definitions for all assigned architecture families."""

from repro.models.config import ModelConfig, get_config, list_archs, register_arch
from repro.models.model import (cache_shapes, cache_structs, decode_step,
                                flat_paths, forward, init_cache, init_params,
                                param_shapes, param_structs, prefill)

__all__ = [
    "ModelConfig", "get_config", "list_archs", "register_arch",
    "cache_shapes", "cache_structs", "decode_step", "flat_paths", "forward",
    "init_cache", "init_params", "param_shapes", "param_structs", "prefill",
]
