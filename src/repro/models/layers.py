"""Shared layer numerics: norms, RoPE, chunked attention, MLP, MoE.

Everything is pure JAX (einsum + lax control flow) with explicit sharding
constraints; per DESIGN.md §4 the paper contributes no model-compute kernel,
so Pallas stays in the storage path.

Attention is double-chunked (outer scan over query blocks, inner scan over KV
blocks, online softmax) so compiled activation memory is O(S·chunk) rather
than O(S²) — required for the 32k-prefill dry-run cells to fit HBM.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models.config import ModelConfig

NEG_INF = -1e30


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _mask_bias(q_pos: jnp.ndarray, kv_pos: jnp.ndarray, window: int,
               prefix_len: int, causal: bool) -> jnp.ndarray:
    """(Sq, C) additive bias: 0 where attendable, NEG_INF elsewhere."""
    q = q_pos[:, None]
    k = kv_pos[None, :]
    if causal:
        ok = k <= q
        if prefix_len > 0:  # prefix-LM: bidirectional over the prefix
            ok = ok | (k < prefix_len)
    else:
        ok = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), dtype=bool)
    if window > 0:
        ok = ok & (q - k < window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      cfg: ModelConfig, *, causal: bool = True,
                      q_offset: int = 0, kv_offset: int = 0,
                      prefix_len: int = 0,
                      kv_len: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Memory-efficient attention.

    q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd). Returns (B, Sq, Hq, hd).
    ``kv_len`` (scalar array) masks out cache positions >= kv_len (decode).
    """
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = hd ** -0.5
    def _fit(n: int, c: int) -> int:
        c = min(c, n)
        while n % c:  # largest divisor <= requested chunk (exact tiling)
            c -= 1
        return c

    qc = _fit(Sq, cfg.attn_chunk)
    kc = _fit(Skv, cfg.attn_chunk)
    n_q, n_k = Sq // qc, Skv // kc

    # bf16 score pipeline (§Perf iteration 2): the materialized (qc x kc)
    # score/prob tiles dominate attention HBM traffic under XLA; computing
    # them in bf16 (f32 softmax statistics and f32 output accumulator keep
    # the numerics anchored) halves that traffic. Enabled only when the
    # model itself runs bf16.
    cdt = jnp.bfloat16 if jnp.dtype(cfg.dtype) == jnp.bfloat16 else jnp.float32

    q = q.reshape(B, n_q, qc, Hkv, G, hd).astype(cdt) * jnp.asarray(scale, cdt)
    k = k.reshape(B, n_k, kc, Hkv, hd)
    v = v.reshape(B, n_k, kc, Hkv, hd)

    def q_block(qi):
        q_blk = q[:, qi]                      # (B, qc, Hkv, G, hd)
        q_pos = q_offset + qi * qc + jnp.arange(qc)

        def kv_step(carry, ki):
            m, l, acc = carry
            k_blk = k[:, ki].astype(cdt)
            v_blk = v[:, ki].astype(cdt)
            kv_pos = kv_offset + ki * kc + jnp.arange(kc)
            s = jnp.einsum("bqhgd,bchd->bqhgc", q_blk, k_blk)  # cdt tile
            bias = _mask_bias(q_pos, kv_pos, cfg.window, prefix_len, causal)
            if kv_len is not None:
                bias = bias + jnp.where(kv_pos[None, :] < kv_len, 0.0, NEG_INF)
            s = s + bias[None, :, None, None, :].astype(cdt)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1).astype(jnp.float32))
            p = jnp.exp(s.astype(jnp.float32) - m_new[..., None]).astype(cdt)
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1, dtype=jnp.float32)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bqhgc,bchd->bqhgd", p, v_blk,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        init = (
            jnp.full((B, qc, Hkv, G), NEG_INF, jnp.float32),
            jnp.zeros((B, qc, Hkv, G), jnp.float32),
            jnp.zeros((B, qc, Hkv, G, hd), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(n_k))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    if n_q == 1:
        out = q_block(0)[:, None]
    else:
        out = jax.lax.map(q_block, jnp.arange(n_q)).transpose(1, 0, 2, 3, 4, 5)
    return out.reshape(B, Sq, Hq, hd)


def attention_layer(x: jnp.ndarray, p: Dict, cfg: ModelConfig, *,
                    positions: jnp.ndarray, causal: bool = True,
                    prefix_len: int = 0,
                    xa: Optional[jnp.ndarray] = None,
                    cache: Optional[Dict] = None,
                    cache_pos: Optional[jnp.ndarray] = None,
                    return_kv: bool = False):
    """Full attention sublayer: proj -> rope -> (cache) -> attention -> out.

    ``xa`` switches to cross-attention (K/V from xa, no RoPE, no causal mask).
    ``cache``: {"k","v"} ring/linear buffers for decode; ``cache_pos`` scalar
    write index. Returns (out, new_cache_or_None, kv_or_None).
    """
    B, S, D = x.shape
    hd = cfg.resolved_head_dim
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    src = xa if xa is not None else x

    # q/k/v constrained on the flattened (H*hd) axis — always divisible by
    # the model axis even when H itself is not (MQA, kv=4).
    # §Perf history: replicating K/V over `model` + sharding query SEQUENCE
    # removed deepseek-prefill's score all-reduces (79.7s -> 42.3s collective)
    # but moved MORE time into HBM streaming of the replicated K/V
    # (iterations 1.1/2.3, net regression on every dense prefill cell —
    # REVERTED). The adopted long-context fix is the Pallas flash kernel
    # (kernels/flash_attention.py), which keeps score tiles in VMEM; the XLA
    # fallback below keeps the baseline sharding and lets SPMD choose.
    q = shard(jnp.einsum("bsd,dh->bsh", x, p["wq"]),
              ("pod", "data"), None, "model").reshape(B, S, Hq, hd)
    k = shard(jnp.einsum("bsd,dh->bsh", src, p["wk"]),
              ("pod", "data"), None, "model").reshape(B, src.shape[1], Hkv, hd)
    v = shard(jnp.einsum("bsd,dh->bsh", src, p["wv"]),
              ("pod", "data"), None, "model").reshape(B, src.shape[1], Hkv, hd)

    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if xa is None:  # self-attention: rotary positions
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    kv_out = (k, v) if return_kv else None
    if cache is not None:
        Sc = cache["k"].shape[1]
        Skv = k.shape[1]
        if Skv >= Sc and cfg.window > 0:
            # prefill overflowing a ring buffer: keep only the last Sc keys,
            # rotated so position p lands in slot p % Sc
            shift = (cache_pos + Skv) % Sc
            ck = jnp.roll(k[:, -Sc:], shift, axis=1)
            cv = jnp.roll(v[:, -Sc:], shift, axis=1)
        else:
            write_idx = cache_pos % Sc if cfg.window > 0 else cache_pos
            ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, write_idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, write_idx, 0, 0))
        new_cache = {"k": ck, "v": cv}
        if S > 1:
            # prefill: causal compute over the prompt itself (chunked);
            # the cache is only *written*, not attended
            out = chunked_attention(q, k, v, cfg, causal=True,
                                    q_offset=0, kv_offset=0,
                                    prefix_len=prefix_len)
        else:
            kv_len = (jnp.minimum(cache_pos + S, Sc) if cfg.window > 0
                      else cache_pos + S)
            out = decode_attention(q, ck, cv, cfg, q_pos=positions,
                                   kv_len=kv_len, ring=cfg.window > 0,
                                   cache_pos=cache_pos)
    else:
        out = chunked_attention(q, k, v, cfg, causal=causal and xa is None,
                                prefix_len=prefix_len)

    out = shard(out.reshape(B, S, Hq * hd), ("pod", "data"), None, "model")
    out = jnp.einsum("bsh,hd->bsd", out.astype(x.dtype), p["wo"])
    return shard(out, ("pod", "data"), None, None), new_cache, kv_out


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     cfg: ModelConfig, *, q_pos: jnp.ndarray,
                     kv_len: jnp.ndarray, ring: bool = False,
                     cache_pos: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Single-token (or short Sq) attention against a cache.

    Linear in cache length; for ring buffers (SWA) positions are recovered
    from the ring layout so RoPE'd keys keep their absolute positions.
    """
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = hd ** -0.5
    q = q.reshape(B, Sq, Hkv, G, hd).astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bchd->bqhgc", q, kf)
    slot = jnp.arange(Skv)
    if ring:
        # slot i holds absolute position: i + floor((cache_pos - i - 1)/Skv + 1)*Skv
        # simpler: valid slots are those written in the last `kv_len` steps.
        age = (cache_pos - slot) % Skv  # steps since written (for current window)
        valid = age < kv_len
    else:
        valid = slot < kv_len
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    s = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgc,bchd->bqhgd", s, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, hd)


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------

def mlp(x: jnp.ndarray, p: Dict, cfg: ModelConfig,
        prefix: str = "w") -> jnp.ndarray:
    if cfg.mlp_type == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p[f"{prefix}_gate"])
        h = jnp.einsum("bsd,df->bsf", x, p[f"{prefix}_in"])
        h = jax.nn.silu(g) * h
    else:
        h = jnp.einsum("bsd,df->bsf", x, p[f"{prefix}_in"])
        h = jax.nn.gelu(h)
    h = shard(h, ("pod", "data"), None, "model")
    return jnp.einsum("bsf,fd->bsd", h, p[f"{prefix}_out"])


def moe(x: jnp.ndarray, p: Dict, cfg: ModelConfig) -> jnp.ndarray:
    """Dropped-token top-K MoE with capacity, scatter/gather dispatch.

    One-hot (T,E,C) dispatch tensors (GShard style) would materialize
    O(T·E·C) floats — hundreds of GB at 1M tokens — so dispatch is a scatter
    into per-expert capacity slots and combine is the mirror gather. Experts
    are sharded over `model`; the scatter/gather crossing from token-sharded
    to expert-sharded layouts is where SPMD inserts the all-to-alls.
    """
    from repro.dist.sharding import get_mesh
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    T = B * S

    # Block-LOCAL dispatch: tokens are split into G blocks aligned with the
    # batch shards; routing, capacity and the scatter/gather all carry the
    # block as a batch dim, so every device dispatches its own tokens locally
    # and only the (G, E, C, D) expert buffers cross the mesh (one all-to-all
    # each way). A single global scatter instead makes GSPMD replicate the
    # (T*K, D) token tensor on every device (measured: 100+GB temp on the
    # MoE prefill cells; §Perf-A).
    mesh = get_mesh()
    G = 1
    if mesh is not None:
        G = mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)
    if T % G:
        G = 1
    Tb = T // G
    C = max(int(cfg.capacity_factor * Tb * K / E), 1)
    C = min(C, Tb)

    xt = shard(x.reshape(G, Tb, D), ("pod", "data"), None, None)
    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    weights, sel = jax.lax.top_k(logits, K)            # (G, Tb, K)
    weights = jax.nn.softmax(weights, axis=-1)

    onehot = jax.nn.one_hot(sel, E, dtype=jnp.float32)      # (G, Tb, K, E)
    pos = (jnp.cumsum(onehot.reshape(G, Tb * K, E), axis=1).reshape(G, Tb, K, E)
           - onehot)
    pos = jnp.einsum("gtke,gtke->gtk", pos, onehot).astype(jnp.int32)
    keep = pos < C
    weights = jnp.where(keep, weights, 0.0)

    # per-block destination slots; overflow drops (capacity per block)
    dest = jnp.where(keep, sel * C + pos, E * C).reshape(G, Tb * K)
    src = jnp.broadcast_to(xt[:, :, None, :], (G, Tb, K, D)).reshape(G, Tb * K, D)
    scatter = jax.vmap(
        lambda d, s: jnp.zeros((E * C, D), x.dtype).at[d].set(s, mode="drop"))
    ex_in = scatter(dest, src).reshape(G, E, C, D)
    # relayout blocks@data -> experts@model: THE all-to-all of MoE
    ex_in = shard(ex_in, None, "model", None, None)

    if cfg.mlp_type == "swiglu":
        g = jnp.einsum("gecd,edf->gecf", ex_in, p["w_gate"])
        h = jnp.einsum("gecd,edf->gecf", ex_in, p["w_in"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", ex_in, p["w_in"]))
    ex_out = jnp.einsum("gecf,efd->gecd", h, p["w_out"])  # (G, E, C, D)
    # relayout back: experts@model -> blocks@data
    ex_out = shard(ex_out, ("pod", "data"), None, None, None)

    gather = jax.vmap(lambda e, d: e.at[d].get(mode="fill", fill_value=0))
    gathered = gather(ex_out.reshape(G, E * C, D), dest)     # (G, Tb*K, D)
    out = jnp.einsum("gtkd,gtk->gtd", gathered.reshape(G, Tb, K, D),
                     weights.astype(x.dtype))

    if cfg.n_shared_experts > 0:
        out = out + mlp(x, p, cfg, prefix="shared_w").reshape(G, Tb, D)
    return out.reshape(B, S, D)
