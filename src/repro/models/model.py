"""Unified model: parameters, forward, prefill and decode for every family.

Families (``cfg.family``):
  dense / moe          decoder-only LM (GQA/MQA/SWA attention, MLP or MoE)
  ssm                  attention-free Mamba2 stack
  hybrid               jamba-style: scan over groups of ``attn_period``
                       sublayers (1 attention + N-1 mamba, alternating MoE/MLP)
  encdec / audio       encoder-decoder; audio frontend is a stub feeding
                       precomputed frame embeddings
  vlm                  decoder LM with a visual-prefix stub (patch embeddings)

Layers are stacked (leading L dim) and executed with ``jax.lax.scan`` so HLO
size / compile time are depth-independent; remat policy per cfg.remat.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import shard
from repro.models.config import ModelConfig
from repro.models.layers import attention_layer, mlp, moe, rmsnorm
from repro.models.ssm import ssm_layer

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Parameter templates
# ---------------------------------------------------------------------------

def _attn_shapes(cfg: ModelConfig, lead: Tuple[int, ...]) -> Dict[str, Tuple]:
    hd = cfg.resolved_head_dim
    s = {
        "wq": lead + (cfg.d_model, cfg.n_heads * hd),
        "wk": lead + (cfg.d_model, cfg.n_kv_heads * hd),
        "wv": lead + (cfg.d_model, cfg.n_kv_heads * hd),
        "wo": lead + (cfg.n_heads * hd, cfg.d_model),
    }
    if cfg.qk_norm:
        s["q_norm"] = lead + (hd,)
        s["k_norm"] = lead + (hd,)
    return s


def _mlp_shapes(cfg: ModelConfig, lead: Tuple[int, ...], prefix: str = "w"
                ) -> Dict[str, Tuple]:
    s = {f"{prefix}_in": lead + (cfg.d_model, cfg.d_ff),
         f"{prefix}_out": lead + (cfg.d_ff, cfg.d_model)}
    if cfg.mlp_type == "swiglu":
        s[f"{prefix}_gate"] = lead + (cfg.d_model, cfg.d_ff)
    return s


def _moe_shapes(cfg: ModelConfig, lead: Tuple[int, ...]) -> Dict[str, Tuple]:
    E = cfg.n_experts
    s = {"router": lead + (cfg.d_model, E),
         "w_in": lead + (E, cfg.d_model, cfg.d_ff),
         "w_out": lead + (E, cfg.d_ff, cfg.d_model)}
    if cfg.mlp_type == "swiglu":
        s["w_gate"] = lead + (E, cfg.d_model, cfg.d_ff)
    if cfg.n_shared_experts:
        s.update(_mlp_shapes(cfg, lead, prefix="shared_w"))
    return s


def _ssm_shapes(cfg: ModelConfig, lead: Tuple[int, ...]) -> Dict[str, Tuple]:
    N, H = cfg.ssm_state, cfg.n_ssm_heads
    conv_dim = cfg.d_inner + 2 * N
    return {
        "in_proj": lead + (cfg.d_model, 2 * cfg.d_inner + 2 * N + H),
        "conv_w": lead + (cfg.ssm_conv_width, conv_dim),
        "A_log": lead + (H,),
        "dt_bias": lead + (H,),
        "D": lead + (H,),
        "norm": lead + (cfg.d_inner,),
        "out_proj": lead + (cfg.d_inner, cfg.d_model),
    }


def param_shapes(cfg: ModelConfig) -> Dict[str, Tuple]:
    """Flat {path: shape} for the whole model."""
    L = cfg.n_layers
    shapes: Dict[str, Tuple] = {"embed/tok": (cfg.vocab_size, cfg.d_model)}

    if cfg.family in ("dense", "moe", "vlm"):
        lead = (L,)
        for k, v in _attn_shapes(cfg, lead).items():
            shapes[f"layers/attn/{k}"] = v
        ffn = _moe_shapes(cfg, lead) if cfg.n_experts else _mlp_shapes(cfg, lead)
        kind = "moe" if cfg.n_experts else "mlp"
        for k, v in ffn.items():
            shapes[f"layers/{kind}/{k}"] = v
        shapes["layers/ln1"] = (L, cfg.d_model)
        shapes["layers/ln2"] = (L, cfg.d_model)

    elif cfg.family == "ssm":
        for k, v in _ssm_shapes(cfg, (L,)).items():
            shapes[f"layers/ssm/{k}"] = v
        shapes["layers/ln1"] = (L, cfg.d_model)

    elif cfg.family == "hybrid":
        period = cfg.attn_period
        ng = L // period
        n_ssm = period - 1
        n_moe = sum(1 for j in range(period) if (j % cfg.moe_period)
                    == cfg.moe_period - 1)
        n_mlp = period - n_moe
        for k, v in _attn_shapes(cfg, (ng,)).items():
            shapes[f"groups/attn/{k}"] = v
        for k, v in _ssm_shapes(cfg, (ng, n_ssm)).items():
            shapes[f"groups/ssm/{k}"] = v
        for k, v in _moe_shapes(cfg, (ng, n_moe)).items():
            shapes[f"groups/moe/{k}"] = v
        for k, v in _mlp_shapes(cfg, (ng, n_mlp)).items():
            shapes[f"groups/mlp/{k}"] = v
        shapes["groups/ln1"] = (ng, period, cfg.d_model)
        shapes["groups/ln2"] = (ng, period, cfg.d_model)

    elif cfg.family in ("encdec", "audio"):
        Le = cfg.n_encoder_layers or L
        for k, v in _attn_shapes(cfg, (Le,)).items():
            shapes[f"enc_layers/attn/{k}"] = v
        for k, v in _mlp_shapes(cfg, (Le,)).items():
            shapes[f"enc_layers/mlp/{k}"] = v
        shapes["enc_layers/ln1"] = (Le, cfg.d_model)
        shapes["enc_layers/ln2"] = (Le, cfg.d_model)
        shapes["enc_final_norm"] = (cfg.d_model,)
        for k, v in _attn_shapes(cfg, (L,)).items():
            shapes[f"dec_layers/attn/{k}"] = v
        for k, v in _attn_shapes(cfg, (L,)).items():
            shapes[f"dec_layers/xattn/{k}"] = v
        for k, v in _mlp_shapes(cfg, (L,)).items():
            shapes[f"dec_layers/mlp/{k}"] = v
        shapes["dec_layers/ln1"] = (L, cfg.d_model)
        shapes["dec_layers/ln_cross"] = (L, cfg.d_model)
        shapes["dec_layers/ln2"] = (L, cfg.d_model)
    else:
        raise ValueError(f"unknown family {cfg.family!r}")

    shapes["final_norm"] = (cfg.d_model,)
    if not cfg.tie_embeddings:
        shapes["lm_head"] = (cfg.d_model, cfg.vocab_size)
    return shapes


def _nested(flat: Dict[str, Any]) -> Params:
    tree: Params = {}
    for path, value in flat.items():
        parts = path.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = value
    return tree


def flat_paths(tree: Params, prefix: str = "") -> Dict[str, Any]:
    out = {}
    for k, v in tree.items():
        path = f"{prefix}/{k}" if prefix else k
        if isinstance(v, dict):
            out.update(flat_paths(v, path))
        else:
            out[path] = v
    return out


def _init_one(path: str, shape: Tuple, cfg: ModelConfig, key) -> jnp.ndarray:
    dtype = jnp.dtype(cfg.dtype)
    last = path.rsplit("/", 1)[-1]
    if last in ("ln1", "ln2", "ln_cross", "final_norm", "enc_final_norm",
                "norm", "q_norm", "k_norm"):
        return jnp.zeros(shape, dtype)          # 1+w convention
    if last == "A_log":
        return jnp.log(jnp.linspace(1.0, 16.0, shape[-1], dtype=jnp.float32)
                       * jnp.ones(shape, jnp.float32)).astype(jnp.float32)
    if last == "dt_bias":
        return jnp.full(shape, -4.6, jnp.float32)   # softplus^-1(0.01)
    if last == "D":
        return jnp.ones(shape, jnp.float32)
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    shapes = param_shapes(cfg)
    root = jax.random.PRNGKey(seed)
    keys = jax.random.split(root, len(shapes))
    flat = {p: _init_one(p, s, cfg, k)
            for (p, s), k in zip(sorted(shapes.items()), keys)}
    return _nested(flat)


def param_structs(cfg: ModelConfig) -> Params:
    """ShapeDtypeStruct pytree (no allocation) — dry-run / AOT input."""
    dtype = jnp.dtype(cfg.dtype)
    f32 = {"A_log", "dt_bias", "D"}
    flat = {}
    for p, s in param_shapes(cfg).items():
        last = p.rsplit("/", 1)[-1]
        dt = jnp.float32 if last in f32 else dtype
        flat[p] = jax.ShapeDtypeStruct(s, dt)
    return _nested(flat)


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------

def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _dense_block(x, lp, cfg: ModelConfig, positions, prefix_len,
                 cache=None, cache_pos=None, causal=True):
    """One dense/moe decoder layer; returns (x, new_cache)."""
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    attn_out, new_cache, _ = attention_layer(
        h, lp["attn"], cfg, positions=positions, causal=causal,
        prefix_len=prefix_len, cache=cache, cache_pos=cache_pos)
    x = x + attn_out
    h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        x = x + moe(h, lp["moe"], cfg)
    else:
        x = x + mlp(h, lp["mlp"], cfg)
    return x, new_cache


def _hybrid_group(x, gp, cfg: ModelConfig, positions, cache=None,
                  cache_pos=None):
    """One jamba group: `attn_period` sublayers, each mixer+FFN."""
    period = cfg.attn_period
    i_ssm = i_moe = i_mlp = 0
    new_cache: Dict[str, Any] = {"attn": None, "ssm_state": [], "ssm_conv": []}
    for j in range(period):
        h = rmsnorm(x, gp["ln1"][j], cfg.norm_eps)
        if j == cfg.attn_offset:
            out, c_attn, _ = attention_layer(
                h, gp["attn"], cfg, positions=positions,
                cache=cache["attn"] if cache else None, cache_pos=cache_pos)
            new_cache["attn"] = c_attn
        else:
            sp = jax.tree_util.tree_map(lambda a: a[i_ssm], gp["ssm"])
            sc = (None if cache is None else
                  {"state": cache["ssm_state"][i_ssm],
                   "conv": cache["ssm_conv"][i_ssm]})
            out, c_ssm = ssm_layer(h, sp, cfg, cache=sc)
            if c_ssm is not None:
                new_cache["ssm_state"].append(c_ssm["state"])
                new_cache["ssm_conv"].append(c_ssm["conv"])
            i_ssm += 1
        x = x + out
        h = rmsnorm(x, gp["ln2"][j], cfg.norm_eps)
        if (j % cfg.moe_period) == cfg.moe_period - 1:
            mp = jax.tree_util.tree_map(lambda a: a[i_moe], gp["moe"])
            x = x + moe(h, mp, cfg)
            i_moe += 1
        else:
            pp = jax.tree_util.tree_map(lambda a: a[i_mlp], gp["mlp"])
            x = x + mlp(h, pp, cfg)
            i_mlp += 1
    if cache is not None:
        new_cache["ssm_state"] = jnp.stack(new_cache["ssm_state"])
        new_cache["ssm_conv"] = jnp.stack(new_cache["ssm_conv"])
    return x, new_cache


def _run_stack(x, layers_params, cfg: ModelConfig, positions, *,
               prefix_len: int = 0, causal: bool = True,
               family: Optional[str] = None, cache=None, cache_pos=None,
               xa=None, xattn_params=None):
    """scan over stacked layers. cache (if given) is scanned alongside."""
    family = family or cfg.family

    def body(carry, inp):
        x = carry
        if cache is None:
            lp = inp
            c = None
        else:
            lp, c = inp
        if family == "hybrid":
            x, new_c = _hybrid_group(x, lp, cfg, positions, cache=c,
                                     cache_pos=cache_pos)
        elif family == "ssm":
            h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
            out, new_c = ssm_layer(h, lp["ssm"], cfg, cache=c)
            x = x + out
        elif family == "encdec_dec":
            h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
            sc = c["self"] if c is not None else None
            out, new_self, _ = attention_layer(
                h, lp["attn"], cfg, positions=positions, causal=True,
                cache=sc, cache_pos=cache_pos)
            x = x + out
            h = rmsnorm(x, lp["ln_cross"], cfg.norm_eps)
            if c is not None and "cross" in c:
                # cross K/V precomputed at prefill: pure read
                out = _cross_from_cache(h, lp["xattn"], cfg, c["cross"])
                new_c = {"self": new_self, "cross": c["cross"]}
            else:
                out, _, kv = attention_layer(
                    h, lp["xattn"], cfg, positions=positions, xa=xa,
                    causal=False, return_kv=c is not None)
                new_c = None if c is None else {"self": new_self,
                                                "cross": {"k": kv[0], "v": kv[1]}}
            x = x + out
            h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
            x = x + mlp(h, lp["mlp"], cfg)
        else:  # dense / moe / vlm / encoder
            x, new_c = _dense_block(x, lp, cfg, positions, prefix_len,
                                    cache=c, cache_pos=cache_pos,
                                    causal=causal)
        return x, new_c

    body = _remat(body, cfg)
    xs = layers_params if cache is None else (layers_params, cache)
    x, new_cache = jax.lax.scan(body, x, xs)
    return x, new_cache


def _cross_from_cache(x, p, cfg: ModelConfig, cross):
    from repro.models.layers import decode_attention
    B, S, D = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, S, cfg.n_heads, hd)
    out = decode_attention(q, cross["k"], cross["v"], cfg,
                           q_pos=jnp.zeros((S,), jnp.int32),
                           kv_len=jnp.array(cross["k"].shape[1]))
    out = jnp.einsum("bsh,hd->bsd",
                     out.reshape(B, S, cfg.n_heads * hd).astype(x.dtype),
                     p["wo"])
    return out


def _embed(cfg: ModelConfig, params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    x = jnp.take(params["embed"]["tok"], tokens, axis=0)
    return shard(x * np.sqrt(cfg.d_model).astype(np.float32),
                 ("pod", "data"), None, None).astype(jnp.dtype(cfg.dtype))


def _unembed(cfg: ModelConfig, params: Params, x: jnp.ndarray) -> jnp.ndarray:
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"]["tok"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return shard(logits, ("pod", "data"), None, "model")


def forward(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray]
            ) -> jnp.ndarray:
    """Training/prefill forward -> logits over the decoder token stream."""
    if cfg.family in ("encdec", "audio"):
        frames = batch["frames"].astype(jnp.dtype(cfg.dtype))
        frames = shard(frames, ("pod", "data"), None, None)
        enc_pos = jnp.arange(frames.shape[1])
        enc, _ = _run_stack(frames, params["enc_layers"], cfg, enc_pos,
                            causal=False, family="dense")
        enc = rmsnorm(enc, params["enc_final_norm"], cfg.norm_eps)
        tokens = batch["tokens"]
        x = _embed(cfg, params, tokens)
        dec_pos = jnp.arange(tokens.shape[1])
        x, _ = _run_stack(x, params["dec_layers"], cfg, dec_pos,
                          family="encdec_dec", xa=enc)
        return _unembed(cfg, params, x)

    if cfg.family == "vlm":
        patches = batch["patches"].astype(jnp.dtype(cfg.dtype))
        patches = shard(patches, ("pod", "data"), None, None)
        tok_x = _embed(cfg, params, batch["tokens"])
        x = jnp.concatenate([patches, tok_x], axis=1)
        positions = jnp.arange(x.shape[1])
        x, _ = _run_stack(x, params["layers"], cfg, positions,
                          prefix_len=cfg.n_prefix_tokens, family="dense")
        x = x[:, cfg.n_prefix_tokens:]
        return _unembed(cfg, params, x)

    tokens = batch["tokens"]
    x = _embed(cfg, params, tokens)
    positions = jnp.arange(tokens.shape[1])
    key = "groups" if cfg.family == "hybrid" else "layers"
    x, _ = _run_stack(x, params[key], cfg, positions)
    return _unembed(cfg, params, x)


# ---------------------------------------------------------------------------
# KV / state caches + decode
# ---------------------------------------------------------------------------

def cache_shapes(cfg: ModelConfig, batch: int, max_len: int,
                 enc_len: int = 0) -> Dict[str, Tuple[Tuple, Any]]:
    """Flat {path: (shape, dtype)} for the decode cache."""
    dtype = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    kv_len = min(max_len, cfg.window) if cfg.window else max_len
    out: Dict[str, Tuple[Tuple, Any]] = {}
    L = cfg.n_layers

    if cfg.family in ("dense", "moe", "vlm"):
        out["k"] = ((L, batch, kv_len, cfg.n_kv_heads, hd), dtype)
        out["v"] = ((L, batch, kv_len, cfg.n_kv_heads, hd), dtype)
    elif cfg.family == "ssm":
        H, P, N = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        conv_dim = cfg.d_inner + 2 * N
        out["state"] = ((L, batch, H, N, P), jnp.float32)
        out["conv"] = ((L, batch, cfg.ssm_conv_width - 1, conv_dim), dtype)
    elif cfg.family == "hybrid":
        ng = L // cfg.attn_period
        n_ssm = cfg.attn_period - 1
        H, P, N = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        conv_dim = cfg.d_inner + 2 * N
        out["attn/k"] = ((ng, batch, kv_len, cfg.n_kv_heads, hd), dtype)
        out["attn/v"] = ((ng, batch, kv_len, cfg.n_kv_heads, hd), dtype)
        out["ssm_state"] = ((ng, n_ssm, batch, H, N, P), jnp.float32)
        out["ssm_conv"] = ((ng, n_ssm, batch, cfg.ssm_conv_width - 1, conv_dim), dtype)
    elif cfg.family in ("encdec", "audio"):
        out["self/k"] = ((L, batch, kv_len, cfg.n_kv_heads, hd), dtype)
        out["self/v"] = ((L, batch, kv_len, cfg.n_kv_heads, hd), dtype)
        out["cross/k"] = ((L, batch, enc_len, cfg.n_kv_heads, hd), dtype)
        out["cross/v"] = ((L, batch, enc_len, cfg.n_kv_heads, hd), dtype)
    return out


def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0):
    flat = {p: jnp.zeros(s, d)
            for p, (s, d) in cache_shapes(cfg, batch, max_len, enc_len).items()}
    return _nested(flat)


def cache_structs(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0):
    flat = {p: jax.ShapeDtypeStruct(s, d)
            for p, (s, d) in cache_shapes(cfg, batch, max_len, enc_len).items()}
    return _nested(flat)


def decode_step(cfg: ModelConfig, params: Params, token: jnp.ndarray,
                cache, pos: jnp.ndarray):
    """One decode step: token (B, 1) + cache at position ``pos`` -> (logits, cache').

    Works for every family; encoder-decoder models read precomputed cross K/V
    from the cache (encoder runs once at prefill)."""
    x = _embed(cfg, params, token)
    positions = pos + jnp.arange(token.shape[1])

    if cfg.family in ("encdec", "audio"):
        x, new_cache = _run_stack(x, params["dec_layers"], cfg, positions,
                                  family="encdec_dec", cache=cache,
                                  cache_pos=pos)
        return _unembed(cfg, params, x)[:, -1], new_cache

    key = "groups" if cfg.family == "hybrid" else "layers"
    x, new_cache = _run_stack(x, params[key], cfg, positions,
                              cache=cache, cache_pos=pos)
    return _unembed(cfg, params, x)[:, -1], new_cache


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray],
            max_len: int):
    """Run the prompt, returning (last-token logits, filled cache).

    Implemented as forward + cache write-out; attention stays chunked."""
    # For simplicity and dry-run purposes we reuse decode-path plumbing with
    # S = prompt length: caches are written at positions [0, S).
    if cfg.family in ("encdec", "audio"):
        frames = batch["frames"].astype(jnp.dtype(cfg.dtype))
        enc_pos = jnp.arange(frames.shape[1])
        enc, _ = _run_stack(frames, params["enc_layers"], cfg, enc_pos,
                            causal=False, family="dense")
        enc = rmsnorm(enc, params["enc_final_norm"], cfg.norm_eps)
        tokens = batch["tokens"]
        B, S = tokens.shape
        # pass only the self-attention cache: the cross K/V are COMPUTED from
        # the encoder output during this pass and returned in the new cache
        cache = init_cache(cfg, B, max_len, enc_len=frames.shape[1])
        x = _embed(cfg, params, tokens)
        x, cache = _run_stack(x, params["dec_layers"], cfg, jnp.arange(S),
                              family="encdec_dec", cache={"self": cache["self"]},
                              cache_pos=jnp.array(0), xa=enc)
        # unembed the LAST position only — prefill never needs (B,S,V) logits
        return _unembed(cfg, params, x[:, -1:])[:, 0], cache

    tokens = batch["tokens"]
    B, S = tokens.shape
    prefix = 0
    x = _embed(cfg, params, tokens)
    if cfg.family == "vlm":  # visual prefix precedes the text prompt
        patches = batch["patches"].astype(jnp.dtype(cfg.dtype))
        patches = shard(patches, ("pod", "data"), None, None)
        x = jnp.concatenate([patches, x], axis=1)
        prefix = cfg.n_prefix_tokens
        S = S + prefix
    cache = init_cache(cfg, B, max_len + prefix)
    key = "groups" if cfg.family == "hybrid" else "layers"
    x, cache = _run_stack(x, params[key], cfg, jnp.arange(S),
                          prefix_len=prefix, cache=cache,
                          cache_pos=jnp.array(0))
    # unembed the LAST position only — prefill never needs (B,S,V) logits
    return _unembed(cfg, params, x[:, -1:])[:, 0], cache
