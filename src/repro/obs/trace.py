"""Trace spans with cross-thread propagation and Chrome-trace export.

A span is a timed region: ``with span("commit.encode", cat="store"):``.
The current span lives in a :mod:`contextvars` ``ContextVar``, so nested
``with`` blocks parent naturally — but ``ThreadPoolExecutor`` workers do
NOT inherit the submitter's context, which is exactly where MGit's hot
paths run (the PR-4 store pool, the PR-2 journal transfer threads, hub
and serve handler threads).  :func:`propagate` closes over the caller's
current span at wrap time and installs it around the callable in the
worker, so pool-side spans parent under the submitting commit/push span
and a traced run exports as ONE connected tree.

Overhead contract (DESIGN.md §14): tracing is off by default and the
disabled path through :func:`span` is a single branch returning a cached
null context manager — no ids, no clocks, no allocation beyond the call
itself.  ``bench_obs`` measures (never asserts) that this keeps commit
throughput within noise of an uninstrumented build.

Export is the Chrome trace-event JSON Perfetto loads directly
(``ph:"X"`` complete events, µs timestamps, per-thread ``thread_name``
metadata).  ``span_id``/``parent_id`` ride in each event's ``args`` so
tests can reconstruct the parent tree without a Perfetto parser.
"""

from __future__ import annotations

import contextvars
import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

__all__ = ["span", "propagate", "enable", "disable", "is_enabled",
           "tracing", "current_span", "reset_trace", "export_chrome_trace",
           "save_trace", "MAX_EVENTS"]

#: Bounded event buffer: a runaway traced loop degrades to dropped events
#: (counted in ``dropped``), never to unbounded memory.
MAX_EVENTS = 200_000


class _State:
    __slots__ = ("enabled", "lock", "events", "next_id", "t0_ns",
                 "thread_names", "dropped")

    def __init__(self) -> None:
        self.enabled = False
        self.lock = threading.Lock()
        self.events: List[Dict[str, Any]] = []
        self.next_id = 1
        self.t0_ns = time.perf_counter_ns()
        self.thread_names: Dict[int, str] = {}
        self.dropped = 0


_state = _State()
_current: contextvars.ContextVar[Optional["_Span"]] = contextvars.ContextVar(
    "mgit_current_span", default=None)


class _NullSpan:
    """Cached no-op context manager handed out while tracing is off."""

    __slots__ = ()

    def __enter__(self) -> None:
        return None

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("name", "cat", "args", "id", "parent_id", "t0", "_token")

    def __init__(self, name: str, cat: str, args: Dict[str, Any]) -> None:
        self.name = name
        self.cat = cat
        self.args = args
        self.id = 0
        self.parent_id: Optional[int] = None
        self.t0 = 0
        self._token: Optional[contextvars.Token] = None

    def __enter__(self) -> "_Span":
        parent = _current.get()
        self.parent_id = parent.id if parent is not None else None
        with _state.lock:
            self.id = _state.next_id
            _state.next_id += 1
        self._token = _current.set(self)
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> bool:
        dur_ns = time.perf_counter_ns() - self.t0
        if self._token is not None:
            _current.reset(self._token)
        t = threading.current_thread()
        ev = {"name": self.name, "cat": self.cat, "ph": "X",
              "ts": (self.t0 - _state.t0_ns) / 1000.0,
              "dur": dur_ns / 1000.0,
              "pid": os.getpid(), "tid": t.ident,
              "args": dict(self.args, span_id=self.id,
                           parent_id=self.parent_id)}
        if exc and exc[0] is not None:
            ev["args"]["error"] = getattr(exc[0], "__name__", str(exc[0]))
        with _state.lock:
            if len(_state.events) < MAX_EVENTS:
                _state.events.append(ev)
                _state.thread_names.setdefault(t.ident, t.name)
            else:
                _state.dropped += 1
        return False


def span(name: str, cat: str = "app", **args):
    """Open a timed span.  When tracing is disabled this is ONE branch
    and a cached null object — the instrumented hot paths stay hot."""
    if not _state.enabled:
        return _NULL_SPAN
    return _Span(name, cat, args)


def propagate(fn):
    """Wrap ``fn`` so it runs under the CALLER's current span even on a
    foreign thread (executors do not copy contextvars).  When tracing is
    off the original callable is returned untouched."""
    if not _state.enabled:
        return fn
    parent = _current.get()

    def _carry(*a, **kw):
        token = _current.set(parent)
        try:
            return fn(*a, **kw)
        finally:
            _current.reset(token)

    return _carry


def enable(on: bool = True) -> None:
    _state.enabled = bool(on)


def disable() -> None:
    _state.enabled = False


def is_enabled() -> bool:
    return _state.enabled


def current_span() -> Optional[_Span]:
    return _current.get()


class tracing:
    """``with tracing():`` — enable for a scope, restore on exit."""

    def __init__(self, on: bool = True) -> None:
        self.on = on
        self._prev = False

    def __enter__(self) -> None:
        self._prev = _state.enabled
        _state.enabled = bool(self.on)

    def __exit__(self, *exc) -> bool:
        _state.enabled = self._prev
        return False


def reset_trace() -> None:
    with _state.lock:
        _state.events = []
        _state.thread_names = {}
        _state.dropped = 0
        _state.next_id = 1
        _state.t0_ns = time.perf_counter_ns()


def export_chrome_trace() -> Dict[str, Any]:
    """Snapshot the buffer as a Perfetto/chrome://tracing document."""
    with _state.lock:
        events = list(_state.events)
        names = dict(_state.thread_names)
        dropped = _state.dropped
    pid = os.getpid()
    meta: List[Dict[str, Any]] = [
        {"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
         "args": {"name": "mgit"}}]
    for tid, tname in sorted(names.items()):
        meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                     "tid": tid, "args": {"name": tname}})
    doc: Dict[str, Any] = {"traceEvents": meta + events,
                           "displayTimeUnit": "ms"}
    if dropped:
        doc["metadata"] = {"dropped_events": dropped}
    return doc


def save_trace(path: str) -> int:
    """Write the Chrome-trace JSON; returns the number of span events."""
    doc = export_chrome_trace()
    with open(path, "w") as f:
        json.dump(doc, f)
    return sum(1 for e in doc["traceEvents"] if e.get("ph") == "X")
