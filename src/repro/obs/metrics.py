"""Process-wide metrics registry: counters, gauges, fixed-bucket histograms.

One :class:`Registry` per process (module-level :data:`REGISTRY`) holds
every metric family; subsystems that used to keep ad-hoc dicts
(``store.io_stats``, ``hub.app.stats``, ``serve.pool.stats_counters``)
now hold a :class:`MetricGroup` — a dict-compatible view whose entries
are registry counters.  Existing call sites (``stats[k] += n`` under the
owner's lock, ``dict(stats)``, ``**stats``) keep working unchanged while
the same numbers become scrapeable through the Prometheus text
exposition (:meth:`Registry.render_prometheus`).

Naming scheme (DESIGN.md §14): ``mgit_<subsystem>_<what>[_<unit>]``,
e.g. ``mgit_store_bytes_materialized``, ``mgit_hub_requests``,
``mgit_http_request_seconds``.  Families are multi-child: each child is
one label set (``instance="3"`` distinguishes the many ArtifactStore
objects a test spins up; daemons add ``route``/``method``).

Record paths are thread-safe and allocation-free in the steady state: a
counter increment is one lock + one int add; a histogram observation is
one lock + a ``bisect`` into pre-built bounds — no per-record dict or
list is created.  Atomic multi-key reads go through
:meth:`MetricGroup.snapshot` / :meth:`MetricGroup.reset`, which hold the
group lock across every key (this is what fixes the torn
``reset_io_stats`` reads the per-key dict mutation loop allowed).
"""

from __future__ import annotations

import bisect
import threading
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

__all__ = ["Registry", "MetricGroup", "Counter", "Gauge", "Histogram",
           "REGISTRY", "DEFAULT_BUCKETS", "render_prometheus"]

# Latency buckets in seconds: 100µs .. 10s, roughly log-spaced.  Fixed at
# family creation so the observe path never grows structures.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025,
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def _escape_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace("\"", "\\\"").replace("\n", "\\n")


def _fmt_labels(labels: Tuple[Tuple[str, str], ...],
                extra: Tuple[Tuple[str, str], ...] = ()) -> str:
    items = labels + extra
    if not items:
        return ""
    body = ",".join(f'{k}="{_escape_label(str(v))}"' for k, v in items)
    return "{" + body + "}"


def _fmt_value(v: float) -> str:
    if isinstance(v, bool):
        return str(int(v))
    if isinstance(v, int) or (isinstance(v, float) and v == int(v)
                              and abs(v) < 1e15):
        return str(int(v))
    return repr(float(v))


def _fmt_le(bound: float) -> str:
    return _fmt_value(bound) if bound != float("inf") else "+Inf"


class Counter:
    """Monotonic-by-convention scalar.  ``set`` exists for the dict-compat
    view (``stats[k] = 0`` style resets route through it)."""

    kind = "counter"
    __slots__ = ("name", "labels", "_lock", "value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...],
                 lock: threading.Lock) -> None:
        self.name = name
        self.labels = labels
        self._lock = lock
        self.value: float = 0

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self.value += n

    def set(self, v: float) -> None:
        with self._lock:
            self.value = v

    def get(self) -> float:
        return self.value


class Gauge(Counter):
    """A value that can go down (pool residency, queue depth)."""

    kind = "gauge"
    __slots__ = ()

    def dec(self, n: float = 1) -> None:
        self.inc(-n)


class Histogram:
    """Fixed-bucket histogram with Prometheus cumulative exposition.

    ``observe`` is the hot path: one lock, one bisect, two adds.
    ``quantile`` applies the same linear-interpolation-within-bucket
    estimate ``histogram_quantile()`` uses server-side, so the p50/p99
    surfaced in ``/api/stats`` match what a Prometheus query would say.
    """

    kind = "histogram"
    __slots__ = ("name", "labels", "_lock", "bounds", "counts", "sum",
                 "count")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...],
                 lock: threading.Lock,
                 buckets: Sequence[float] = DEFAULT_BUCKETS) -> None:
        self.name = name
        self.labels = labels
        self._lock = lock
        self.bounds: List[float] = sorted(float(b) for b in buckets)
        self.counts: List[int] = [0] * (len(self.bounds) + 1)  # last: +Inf
        self.sum: float = 0.0
        self.count: int = 0

    def observe(self, v: float) -> None:
        with self._lock:
            self.counts[bisect.bisect_left(self.bounds, v)] += 1
            self.sum += v
            self.count += 1

    def snapshot(self) -> Tuple[List[int], float, int]:
        with self._lock:
            return list(self.counts), self.sum, self.count

    def quantile(self, q: float) -> Optional[float]:
        """Estimate the q-quantile (0..1) by linear interpolation inside
        the bucket the target rank falls in; observations beyond the last
        finite bound clamp to it (Prometheus semantics)."""
        counts, _, total = self.snapshot()
        if total == 0:
            return None
        target = q * total
        cum = 0
        for i, c in enumerate(counts):
            cum += c
            if cum >= target and c > 0:
                if i == len(self.bounds):        # +Inf bucket
                    return self.bounds[-1]
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = self.bounds[i]
                return lo + (hi - lo) * ((target - (cum - c)) / c)
        return self.bounds[-1]


class Registry:
    """All metric families of one process, keyed by family name.

    A family is (kind, help, buckets) plus one child metric per distinct
    label set; re-requesting the same (name, labels) returns the same
    child, so instrumentation sites don't need to cache handles (though
    hot paths should)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._families: Dict[str, Dict[str, Any]] = {}
        self._instances: Dict[str, int] = {}

    # -- family / child construction -----------------------------------
    def _child(self, cls, name: str, help: str, labels: Dict[str, str],
               lock: Optional[threading.Lock] = None, **kw):
        key = tuple(sorted((k, str(v)) for k, v in labels.items()))
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = {"kind": cls.kind, "help": help, "children": {}}
                self._families[name] = fam
            elif fam["kind"] != cls.kind:
                raise ValueError(
                    f"metric {name!r} already registered as {fam['kind']}")
            child = fam["children"].get(key)
            if child is None:
                child = cls(name, key, lock or threading.Lock(), **kw)
                fam["children"][key] = child
            return child

    def counter(self, name: str, help: str = "",
                lock: Optional[threading.Lock] = None, **labels) -> Counter:
        return self._child(Counter, name, help, labels, lock=lock)

    def gauge(self, name: str, help: str = "",
              lock: Optional[threading.Lock] = None, **labels) -> Gauge:
        return self._child(Gauge, name, help, labels, lock=lock)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS,
                  **labels) -> Histogram:
        return self._child(Histogram, name, help, labels, buckets=buckets)

    def next_instance(self, namespace: str) -> str:
        """Monotonic per-namespace id so many live objects (stores in a
        test run) keep disjoint label sets in one shared registry."""
        with self._lock:
            n = self._instances.get(namespace, 0)
            self._instances[namespace] = n + 1
            return str(n)

    def group(self, namespace: str, keys: Sequence[str] = (),
              help: str = "", instance: Optional[str] = None) -> "MetricGroup":
        return MetricGroup(self, namespace, keys=keys, help=help,
                           instance=instance)

    # -- exposition ----------------------------------------------------
    def collect(self):
        with self._lock:
            return [(name, fam["kind"], fam["help"],
                     list(fam["children"].values()))
                    for name, fam in sorted(self._families.items())]

    def render_prometheus(self) -> str:
        out: List[str] = []
        for name, kind, help, children in self.collect():
            if help:
                out.append(f"# HELP {name} {help}")
            out.append(f"# TYPE {name} {kind}")
            for m in children:
                if kind == "histogram":
                    counts, total_sum, total = m.snapshot()
                    cum = 0
                    bounds = m.bounds + [float("inf")]
                    for b, c in zip(bounds, counts):
                        cum += c
                        lab = _fmt_labels(m.labels, (("le", _fmt_le(b)),))
                        out.append(f"{name}_bucket{lab} {cum}")
                    lab = _fmt_labels(m.labels)
                    out.append(f"{name}_sum{lab} {_fmt_value(total_sum)}")
                    out.append(f"{name}_count{lab} {total}")
                else:
                    lab = _fmt_labels(m.labels)
                    out.append(f"{name}{lab} {_fmt_value(m.get())}")
        return "\n".join(out) + "\n"


class MetricGroup:
    """Dict-compatible view over a namespace of registry counters.

    Supports every pattern the legacy stats dicts were used with —
    ``g[k] += n`` (owner-lock serialized), ``g.get(k, 0)``, ``dict(g)``,
    ``**g``, ``for k in g`` — plus :meth:`snapshot` and :meth:`reset`
    that hold ONE lock across all keys, which the per-key mutation loop
    they replace could not do.  Unknown keys materialize on first write
    (the hub counts dynamic keys like per-status rejections)."""

    def __init__(self, registry: Registry, namespace: str,
                 keys: Sequence[str] = (), help: str = "",
                 instance: Optional[str] = None) -> None:
        self._registry = registry
        self._namespace = namespace
        self._help = help
        self.instance = (registry.next_instance(namespace)
                         if instance is None else instance)
        self._lock = threading.Lock()
        self._metrics: Dict[str, Counter] = {}
        for k in keys:
            self._ensure(k)

    def _ensure(self, key: str) -> Counter:
        m = self._metrics.get(key)
        if m is None:
            # every child shares the group lock, so snapshot()/reset()
            # exclude concurrent increments on ANY key of the group
            m = self._registry.counter(f"{self._namespace}_{key}",
                                       help=self._help, lock=self._lock,
                                       instance=self.instance)
            with self._lock:  # keep snapshot() iteration safe
                self._metrics[key] = m
        return m

    # -- dict protocol -------------------------------------------------
    def __getitem__(self, key: str) -> float:
        return self._metrics[key].get()

    def __setitem__(self, key: str, value: float) -> None:
        self._ensure(key).set(value)

    def __contains__(self, key: str) -> bool:
        return key in self._metrics

    def __iter__(self) -> Iterator[str]:
        return iter(list(self._metrics))

    def __len__(self) -> int:
        return len(self._metrics)

    def keys(self):
        return list(self._metrics)

    def items(self):
        return [(k, m.get()) for k, m in self._metrics.items()]

    def values(self):
        return [m.get() for m in self._metrics.values()]

    def get(self, key: str, default: float = 0) -> float:
        m = self._metrics.get(key)
        return default if m is None else m.get()

    def __eq__(self, other: object) -> bool:
        if isinstance(other, MetricGroup):
            return self.snapshot() == other.snapshot()
        if isinstance(other, dict):
            return self.snapshot() == other
        return NotImplemented

    def __repr__(self) -> str:
        return f"MetricGroup({self._namespace}, {self.snapshot()!r})"

    # -- atomic multi-key operations ----------------------------------
    def inc(self, key: str, n: float = 1) -> None:
        self._ensure(key).inc(n)

    def snapshot(self) -> Dict[str, float]:
        """All keys read under one lock — no torn multi-key view.
        Field access is direct: the metrics share this very lock."""
        with self._lock:
            return {k: m.value for k, m in self._metrics.items()}

    def reset(self) -> Dict[str, float]:
        """Zero every key under one lock; returns the pre-reset values."""
        with self._lock:
            before = {}
            for k, m in self._metrics.items():
                before[k] = m.value
                m.value = 0
            return before


#: The process-wide default registry every subsystem records into.
REGISTRY = Registry()


def render_prometheus() -> str:
    return REGISTRY.render_prometheus()
