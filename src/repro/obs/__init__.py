"""Unified observability layer: metrics registry + trace spans (DESIGN.md §14).

Import surface for the rest of the codebase::

    from repro.obs import REGISTRY, MetricGroup, span, propagate

Metrics live in one process-wide :data:`REGISTRY`; legacy stats dicts
are :class:`MetricGroup` compat views over it, so the same counters the
tests assert on are scrapeable as Prometheus text via ``GET
/api/metrics`` on the hub and serve daemons (or ``cli obs metrics`` for
an offline repo).  Trace spans export Chrome-trace/Perfetto JSON via
``cli obs trace``.
"""

from repro.obs.metrics import (DEFAULT_BUCKETS, REGISTRY, Counter, Gauge,
                               Histogram, MetricGroup, Registry,
                               render_prometheus)
from repro.obs.trace import (MAX_EVENTS, current_span, disable, enable,
                             export_chrome_trace, is_enabled, propagate,
                             reset_trace, save_trace, span, tracing)

__all__ = [
    "DEFAULT_BUCKETS", "REGISTRY", "Counter", "Gauge", "Histogram",
    "MetricGroup", "Registry", "render_prometheus",
    "MAX_EVENTS", "current_span", "disable", "enable",
    "export_chrome_trace", "is_enabled", "propagate", "reset_trace",
    "save_trace", "span", "tracing",
]
