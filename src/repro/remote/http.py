"""HttpTransport — the network implementation of :class:`Transport`.

Speaks the hub daemon's REST surface (:mod:`repro.hub`; protocol table in
DESIGN.md §11.2) over stdlib ``http.client`` — no third-party deps. Every
:class:`~repro.remote.transport.Transport` method maps onto one endpoint,
so ``push``/``pull``/``clone`` and the §8.4 resumable journal run unchanged
over the network: the push journal lives server-side (the receiver), object
uploads from the journalled thread pool land as parallel ``POST`` requests,
and an interrupted transfer resumes through the same closure-keyed journal
id on the next attempt.

Wire format for multi-object moves is the *pack record stream* — the same
self-describing ``[keylen u16][key][datalen u32][data]`` framing the CAS
packfiles use (:data:`WIRE_REC_HEAD` == ``cas._REC_HEAD``), streamed with an
exact ``Content-Length`` so neither side ever buffers more than one object.
Tensor/delta payloads are already LZMA/npy bytes and do not recompress;
JSON bodies and responses ride gzip content-encoding above a size floor.

Reliability:

* **retry-with-backoff** — connection errors and 5xx responses retry with
  exponential backoff; every endpoint is idempotent (content-addressed
  writes, conditional publish), so replaying a request that half-completed
  is always safe;
* **optimistic lineage swap** — ``publish_lineage(payload, expected=etag)``
  sends ``If-Match``; the hub answers ``409 Conflict`` when the document
  moved, surfaced as :class:`PublishConflict` for the sync engine's
  re-fetch/re-merge/retry loop (§11.3).

Only *stored* artifact bytes cross this transport (manifests, tensors,
delta blobs by CAS key) — never in-memory models, whose params differ from
their stored form by commit-time quantization eps.
"""

from __future__ import annotations

import gzip
import http.client
import json
import os
import struct
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, Iterable, Iterator, List, Mapping, Optional
from typing import Sequence, Set, Tuple
from urllib.parse import urlsplit

from repro.obs import REGISTRY, propagate, span
from repro.remote.transport import (ETAG_ABSENT, PublishConflict, Transport,
                                    lineage_etag)

#: record framing for multi-object streams: (keylen u16, datalen u32) —
#: identical to the CAS packfile record head, so a hub could in principle
#: splice a received stream straight into a pack
WIRE_REC_HEAD = struct.Struct("<HI")

#: JSON bodies/responses below this size skip gzip (header overhead wins)
GZIP_FLOOR = 256

#: env var consulted for a bearer token when none is passed explicitly
TOKEN_ENV = "MGIT_HUB_TOKEN"


class HubUnavailable(ConnectionError):
    """The hub could not be reached after all retries."""


def endpoint_family(path: str) -> str:
    """Bounded label for per-endpoint-family retry accounting."""
    p = path.split("?", 1)[0]
    for prefix, family in (("/api/objects", "objects"),
                           ("/api/journal", "journal"),
                           ("/api/lineage", "lineage"),
                           ("/api/have", "negotiate"),
                           ("/api/finalize", "finalize"),
                           ("/api/ping", "ping")):
        if p.startswith(prefix):
            return family
    return "other"


def encode_records(objects: Mapping[str, bytes]) -> bytes:
    """Serialize a key->bytes mapping as one pack record stream."""
    parts: List[bytes] = []
    for key, data in objects.items():
        kb = key.encode()
        parts.append(WIRE_REC_HEAD.pack(len(kb), len(data)))
        parts.append(kb)
        parts.append(data)
    return b"".join(parts)


def iter_records(buf: bytes) -> Iterator[Tuple[str, bytes]]:
    """Parse a pack record stream; a torn tail raises (wire corruption —
    unlike pack-file tail scans there is no crash to forgive here)."""
    pos, end = 0, len(buf)
    while pos < end:
        if pos + WIRE_REC_HEAD.size > end:
            raise ValueError("torn record head in object stream")
        klen, dlen = WIRE_REC_HEAD.unpack_from(buf, pos)
        pos += WIRE_REC_HEAD.size
        if pos + klen + dlen > end:
            raise ValueError("torn record body in object stream")
        key = buf[pos:pos + klen].decode()
        pos += klen
        yield key, buf[pos:pos + dlen]
        pos += dlen


class HttpTransport(Transport):
    """Peer repository served by an MGit hub daemon at ``http://host:port``.

    ``token`` (or ``$MGIT_HUB_TOKEN``) is sent as a bearer token; the hub's
    auth stub rejects mismatches with 401 (raised as ``PermissionError``).
    """

    def __init__(self, url: str, token: Optional[str] = None,
                 timeout: float = 30.0, retries: int = 4,
                 backoff: float = 0.25) -> None:
        self.url = url.rstrip("/")
        parts = urlsplit(self.url)
        if parts.scheme not in ("http", "https"):
            raise ValueError(f"not an http(s) url: {url!r}")
        self._host = parts.hostname or "127.0.0.1"
        self._port = parts.port or (443 if parts.scheme == "https" else 80)
        self._https = parts.scheme == "https"
        self._prefix = parts.path.rstrip("/")
        self.token = token if token is not None else os.environ.get(TOKEN_ENV)
        self.timeout = timeout
        self.retries = retries
        self.backoff = backoff
        # retry observability (ISSUE 8): per-endpoint-family counts of
        # retried attempts, seconds slept in backoff, and requests that
        # exhausted every retry. Instance-local (surfaced per-sync through
        # SyncReport via retry_stats()); mirrored into process-wide
        # mgit_http_retry* registry counters for /api/metrics.
        self._retry_lock = threading.Lock()
        self._retries: Dict[str, int] = {}
        self._backoff_s: Dict[str, float] = {}
        self._terminal: Dict[str, int] = {}

    def _record_retry(self, family: str, sleep_s: float) -> None:
        with self._retry_lock:
            self._retries[family] = self._retries.get(family, 0) + 1
            self._backoff_s[family] = (self._backoff_s.get(family, 0.0)
                                       + sleep_s)
        REGISTRY.counter("mgit_http_retries",
                         help="retried hub requests", family=family).inc()
        REGISTRY.counter("mgit_http_backoff_seconds",
                         help="seconds slept in retry backoff",
                         family=family).inc(sleep_s)

    def _record_terminal(self, family: str) -> None:
        with self._retry_lock:
            self._terminal[family] = self._terminal.get(family, 0) + 1
        REGISTRY.counter("mgit_http_terminal_failures",
                         help="hub requests that exhausted all retries",
                         family=family).inc()

    def retry_stats(self) -> Dict[str, Any]:
        """Per-family retry/backoff/terminal-failure counts so far."""
        with self._retry_lock:
            return {"retries": dict(self._retries),
                    "backoff_s": {k: round(v, 3)
                                  for k, v in self._backoff_s.items()},
                    "terminal_failures": dict(self._terminal)}

    # -- one HTTP round-trip with retry/backoff -----------------------------
    def _connect(self) -> http.client.HTTPConnection:
        cls = (http.client.HTTPSConnection if self._https
               else http.client.HTTPConnection)
        return cls(self._host, self._port, timeout=self.timeout)

    def _request(self, method: str, path: str, body: Optional[bytes] = None,
                 headers: Optional[Dict[str, str]] = None,
                 json_body: Optional[Dict] = None,
                 ) -> Tuple[int, Dict[str, str], bytes]:
        """Returns ``(status, lowered-headers, decoded body)``.

        Retries (connection refused/reset, timeouts, 5xx) with exponential
        backoff; 4xx statuses return to the caller for semantic mapping.
        A fresh connection per request keeps the transport trivially
        thread-safe for the journalled transfer's parallel chunk workers."""
        hdrs = {"Accept-Encoding": "gzip", "Connection": "close"}
        if self.token:
            hdrs["Authorization"] = f"Bearer {self.token}"
        if json_body is not None:
            body = json.dumps(json_body).encode()
            hdrs["Content-Type"] = "application/json"
            if len(body) > GZIP_FLOOR:
                body = gzip.compress(body, 5)
                hdrs["Content-Encoding"] = "gzip"
        if headers:
            hdrs.update(headers)
        family = endpoint_family(path)
        last_exc: Optional[BaseException] = None
        for attempt in range(self.retries + 1):
            try:
                conn = self._connect()
                try:
                    conn.request(method, self._prefix + path, body=body,
                                 headers=hdrs)
                    resp = conn.getresponse()
                    data = resp.read()
                    status = resp.status
                    resp_headers = {k.lower(): v
                                    for k, v in resp.getheaders()}
                finally:
                    conn.close()
                if resp_headers.get("content-encoding") == "gzip":
                    data = gzip.decompress(data)
                if status >= 500:
                    raise HubUnavailable(
                        f"{method} {path} -> {status}: {data[:200]!r}")
                return status, resp_headers, data
            except (OSError, http.client.HTTPException) as exc:
                last_exc = exc
                if attempt < self.retries:
                    sleep_s = self.backoff * (2 ** attempt)
                    self._record_retry(family, sleep_s)
                    time.sleep(sleep_s)
        self._record_terminal(family)
        raise HubUnavailable(
            f"hub at {self.url} unreachable after "
            f"{self.retries + 1} attempts: {last_exc}") from last_exc

    def _json(self, data: bytes) -> Dict:
        return json.loads(data) if data else {}

    @staticmethod
    def _check_auth(status: int, path: str) -> None:
        if status == 401:
            raise PermissionError(f"hub rejected token for {path}")

    # -- Transport ----------------------------------------------------------
    def ensure_repo(self) -> None:
        """The hub owns its repo directory; just verify it is serving."""
        status, _, data = self._request("GET", "/api/ping")
        self._check_auth(status, "/api/ping")
        if status != 200 or not self._json(data).get("ok"):
            raise HubUnavailable(f"{self.url} is not an mgit hub "
                                 f"(status {status})")

    def fetch_lineage(self) -> Optional[Dict]:
        return self.fetch_lineage_versioned()[0]

    def fetch_lineage_versioned(self) -> Tuple[Optional[Dict], str]:
        status, headers, data = self._request("GET", "/api/lineage")
        self._check_auth(status, "/api/lineage")
        if status == 404:
            return None, headers.get("etag", ETAG_ABSENT)
        payload = self._json(data)
        return payload, headers.get("etag") or lineage_etag(payload)

    def publish_lineage(self, payload: Dict,
                        expected: Optional[str] = None) -> Optional[Dict]:
        headers = {"If-Match": expected} if expected is not None else {}
        status, _, data = self._request("PUT", "/api/lineage",
                                        json_body=payload, headers=headers)
        self._check_auth(status, "/api/lineage")
        if status == 409:
            raise PublishConflict(self._json(data).get("etag", "?"))
        if status not in (200, 204):
            raise HubUnavailable(f"publish failed: {status} {data[:200]!r}")
        # the hub's acknowledgement: its etag of what it ACTUALLY published
        # plus any nodes its quarantine policy rejected (§11.3)
        return self._json(data)

    def have(self, keys: Sequence[str]) -> Set[str]:
        status, _, data = self._request("POST", "/api/have",
                                        json_body={"keys": list(keys)})
        self._check_auth(status, "/api/have")
        return set(self._json(data).get("have", []))

    def read_objects(self, keys: Sequence[str]) -> Dict[str, bytes]:
        if not keys:
            return {}
        status, _, data = self._request("POST", "/api/objects/mget",
                                        json_body={"keys": list(keys)})
        self._check_auth(status, "/api/objects/mget")
        if status == 404:
            missing = self._json(data).get("missing", list(keys))
            raise KeyError(f"hub is missing objects: {missing[:5]}")
        out = dict(iter_records(data))
        if len(out) != len(set(keys)):
            raise KeyError(f"hub returned {len(out)}/{len(set(keys))} objects")
        return out

    def object_sizes(self, keys: Sequence[str]) -> Dict[str, int]:
        if not keys:
            return {}
        status, _, data = self._request("POST", "/api/objects/sizes",
                                        json_body={"keys": list(keys)})
        self._check_auth(status, "/api/objects/sizes")
        if status == 404:
            # pre-chunk-layer hub without the endpoint: sizes unknown —
            # the pull planner falls back to single-stream mget
            return {}
        return {k: int(v)
                for k, v in self._json(data).get("sizes", {}).items()}

    def read_object_parallel(self, key: str, size: int,
                             part_bytes: int = 1 * 2 ** 20,
                             workers: int = 4) -> bytes:
        """Fetch one large object as concurrent ranged GETs, in-order join.

        Each part is an independent ``Range`` request on its own connection
        (``_request`` opens a fresh one per call, so the fan-out is safe);
        on loopback this mostly overlaps server-side pread with client-side
        socket drain, over real links it fills the bandwidth-delay product
        the way aria2-style segmented downloads do. ``size`` must be the
        object's stored length (from :meth:`object_sizes`) — the reassembled
        buffer is length-checked against it, and content addressing verifies
        the payload end-to-end when it lands in the local CAS."""
        if size <= part_bytes:
            return self.read_object_range(key, 0, size)
        spans = [(off, min(part_bytes, size - off))
                 for off in range(0, size, part_bytes)]
        with span("http.ranged_pull", cat="remote", key=key,
                  parts=len(spans)):
            one = propagate(
                lambda s: self.read_object_range(key, s[0], s[1]))
            with ThreadPoolExecutor(max_workers=max(1, workers),
                                    thread_name_prefix="range-get") as pool:
                parts = list(pool.map(one, spans))
        data = b"".join(parts)
        if len(data) != size:
            raise HubUnavailable(
                f"ranged fetch of {key!r} reassembled {len(data)} bytes, "
                f"expected {size}")
        return data

    def read_object_range(self, key: str, start: int,
                          length: Optional[int] = None) -> bytes:
        """Ranged single-object read (zero-copy server-side off the mmap
        pool) — the building block for byte-level resume of huge tensors."""
        end = "" if length is None else str(start + length - 1)
        status, _, data = self._request(
            "GET", f"/api/objects/{key}",
            headers={"Range": f"bytes={start}-{end}"})
        self._check_auth(status, "/api/objects")
        if status == 404:
            raise KeyError(f"no object {key!r} on hub")
        if status == 416:
            return b""  # resume positioned at EOF: nothing left to fetch
        if status not in (200, 206):
            raise HubUnavailable(f"ranged read failed: {status}")
        return data

    def write_objects(self, objects: Mapping[str, bytes]) -> None:
        if not objects:
            return
        body = encode_records(objects)
        status, _, data = self._request(
            "POST", "/api/objects", body=body,
            headers={"Content-Type": "application/x-mgit-pack"})
        self._check_auth(status, "/api/objects")
        if status != 200:
            raise HubUnavailable(f"object upload failed: {status} "
                                 f"{data[:200]!r}")

    def finalize(self, roots: Sequence[str]) -> None:
        # The hub derives the authoritative root set from its *current*
        # lineage document (§11.3): with concurrent pushers, a client's view
        # of the roots may already be stale by the time its finalize lands.
        status, _, data = self._request("POST", "/api/finalize",
                                        json_body={"roots": list(roots)})
        self._check_auth(status, "/api/finalize")
        if status != 200:
            raise HubUnavailable(f"finalize failed: {status} {data[:200]!r}")

    # -- journal (server-side: the hub is the receiver of a push) -----------
    def journal_load(self, transfer_id: str) -> Optional[Dict]:
        status, _, data = self._request("GET", f"/api/journal/{transfer_id}")
        self._check_auth(status, "/api/journal")
        return None if status == 404 else self._json(data)

    def journal_write(self, transfer_id: str, payload: Dict) -> None:
        status, _, _ = self._request("PUT", f"/api/journal/{transfer_id}",
                                     json_body=payload)
        self._check_auth(status, "/api/journal")

    def journal_clear(self, transfer_id: str) -> None:
        status, _, _ = self._request("DELETE",
                                     f"/api/journal/{transfer_id}")
        self._check_auth(status, "/api/journal")

    def journal_list(self) -> Sequence[str]:
        status, _, data = self._request("GET", "/api/journal")
        self._check_auth(status, "/api/journal")
        return self._json(data).get("transfers", [])

    # -- extras --------------------------------------------------------------
    def server_stats(self) -> Dict:
        """The hub's live request/byte counters (``mgit hub stats``)."""
        status, _, data = self._request("GET", "/api/stats")
        self._check_auth(status, "/api/stats")
        return self._json(data)

    def list_repos(self) -> List[Dict]:
        """Tenants of a multi-tenant hub: ``[{"name", "etag"}, ...]``.

        A single-repo hub answers with its sole ``default`` entry, so
        replica sync (§16.5) iterates the same way against either."""
        status, _, data = self._request("GET", "/api/repos")
        self._check_auth(status, "/api/repos")
        if status != 200:
            raise HubUnavailable(f"repo list failed: {status}")
        return self._json(data).get("repos", [])

    def run_gc(self, confirm_cycles: int = 2, grace: int = 1) -> Dict:
        """Trigger one maintenance GC cycle on a live hub (§16.3)."""
        status, _, data = self._request(
            "POST", "/api/gc", json_body={"confirm_cycles": confirm_cycles,
                                          "grace": grace})
        self._check_auth(status, "/api/gc")
        if status != 200:
            raise HubUnavailable(f"gc failed: {status} {data[:200]!r}")
        return self._json(data)

    def run_compact(self) -> Dict:
        """Trigger aggressive pack compaction on a live hub (§16.3)."""
        status, _, data = self._request("POST", "/api/compact", json_body={})
        self._check_auth(status, "/api/compact")
        if status != 200:
            raise HubUnavailable(f"compact failed: {status} {data[:200]!r}")
        return self._json(data)

    def replica_sync(self) -> Dict:
        """Trigger an on-demand mirror pass on a replica hub (§16.5)."""
        status, _, data = self._request("POST", "/api/replica/sync",
                                        json_body={})
        self._check_auth(status, "/api/replica/sync")
        if status != 200:
            raise HubUnavailable(f"replica sync failed: {status}")
        return self._json(data)
