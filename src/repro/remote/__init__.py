"""MGit remote sync: push/pull of lineage subgraphs with CAS negotiation.

The collaboration pillar (paper §5, DESIGN.md §8 + §11): a byte-oriented
:class:`Transport` to a peer repository (filesystem ``LocalTransport`` or
network :class:`HttpTransport` against a :mod:`repro.hub` daemon), have/want
object negotiation over manifest closures, resumable journalled transfer,
optimistic lineage swap for concurrent pushers, and a three-way
lineage-metadata merge on pull that reuses the §5 conflict classification.
Everything that crosses a transport is a *stored* artifact object — the
delta-quantized form committed to the CAS, not in-memory params.
"""

from repro.remote.http import HttpTransport, HubUnavailable
from repro.remote.journal import LocalJournalStore, chunk_id, transfer_id
from repro.remote.negotiate import TransferPlan, plan_transfer, walk_manifests
from repro.remote.sync import (LineageMergeReport, NodeMergeOutcome,
                               RemoteState, SyncReport, clone, merge_lineage,
                               pull, push, remote_add, remote_list,
                               remote_remove, resolve_transport)
from repro.remote.transport import (ETAG_ABSENT, LocalTransport,
                                    PublishConflict, Transport, lineage_etag)

__all__ = [
    "Transport", "LocalTransport", "HttpTransport", "HubUnavailable",
    "PublishConflict", "lineage_etag", "ETAG_ABSENT",
    "TransferPlan", "plan_transfer", "walk_manifests",
    "LocalJournalStore", "chunk_id", "transfer_id",
    "SyncReport", "LineageMergeReport", "NodeMergeOutcome", "RemoteState",
    "push", "pull", "clone", "merge_lineage",
    "remote_add", "remote_list", "remote_remove", "resolve_transport",
]
