"""MGit remote sync: push/pull of lineage subgraphs with CAS negotiation.

The collaboration pillar (paper §5, DESIGN.md §8): a byte-oriented
:class:`Transport` to a peer repository, have/want object negotiation over
manifest closures, resumable journalled transfer, and a three-way
lineage-metadata merge on pull that reuses the §5 conflict classification.
"""

from repro.remote.journal import LocalJournalStore, chunk_id, transfer_id
from repro.remote.negotiate import TransferPlan, plan_transfer, walk_manifests
from repro.remote.sync import (LineageMergeReport, NodeMergeOutcome,
                               RemoteState, SyncReport, clone, merge_lineage,
                               pull, push, remote_add, remote_list,
                               remote_remove, resolve_transport)
from repro.remote.transport import LocalTransport, Transport

__all__ = [
    "Transport", "LocalTransport",
    "TransferPlan", "plan_transfer", "walk_manifests",
    "LocalJournalStore", "chunk_id", "transfer_id",
    "SyncReport", "LineageMergeReport", "NodeMergeOutcome", "RemoteState",
    "push", "pull", "clone", "merge_lineage",
    "remote_add", "remote_list", "remote_remove", "resolve_transport",
]
