"""Transport — the wire interface between a local repo and a remote peer.

Every method is one protocol round-trip and moves only bytes and keys, never
live *objects*: ``have`` answers the negotiation (DESIGN.md §8.2),
``read_objects``/``write_objects`` move CAS payloads in batches,
``fetch_lineage``/``publish_lineage`` exchange the graph metadata document,
and the ``journal_*`` trio persists transfer progress on the receiving side
so an interrupted push resumes instead of restarting (§8.4). Only *stored*
artifacts ever cross a transport — commit-time delta quantization means an
in-memory model and its stored form differ by eps, so bit-identity across
peers is always judged on store-loaded params, never ``node.artifact``.
The interface maps 1:1 onto HTTP endpoints (see the protocol table in
DESIGN.md §11.2); :class:`~repro.remote.http.HttpTransport` is the network
implementation against a hub daemon (:mod:`repro.hub`).

Concurrent writers are serialized by *optimistic lineage swap* (§11.3):
``fetch_lineage_versioned`` returns the document together with an etag
(:func:`lineage_etag`, a content hash of the canonical JSON), and
``publish_lineage(payload, expected=etag)`` replaces the document only if
it still carries that etag — otherwise :class:`PublishConflict` is raised
and the sync engine re-fetches, re-merges and retries. Object uploads need
no such guard: they are content-addressed and idempotent.

:class:`LocalTransport` is the filesystem implementation: the remote is just
another repo directory, opened through its own :class:`ArtifactStore` — which
is also what the hub daemon does on its side of an HTTP transport.
"""

from __future__ import annotations

import json
import os
import threading
from abc import ABC, abstractmethod
from typing import Dict, Mapping, Optional, Sequence, Set, Tuple

from repro.common.hashing import bytes_hash
from repro.store.artifact_store import ArtifactStore

#: etag of an absent lineage document (fresh remote, nothing published yet)
ETAG_ABSENT = "absent"


def lineage_etag(payload: Optional[Dict]) -> str:
    """Version tag of a lineage document: content hash of canonical JSON.

    A pure function of the payload, so every implementation (local file,
    hub server, client cache) derives the same tag for the same document —
    the compare-and-swap in :meth:`Transport.publish_lineage` never depends
    on clocks or counters."""
    if payload is None:
        return ETAG_ABSENT
    return bytes_hash(json.dumps(payload, sort_keys=True).encode())[:32]


class PublishConflict(Exception):
    """Optimistic lineage swap failed: the document moved under us.

    Carries the remote's *current* etag; the caller re-fetches, re-merges
    against the new document and retries (HTTP surfaces this as 409)."""

    def __init__(self, current_etag: str,
                 message: str = "lineage moved under publish") -> None:
        super().__init__(f"{message} (current etag {current_etag})")
        self.current_etag = current_etag


class Transport(ABC):
    """Abstract peer repository endpoint."""

    url: str

    @abstractmethod
    def ensure_repo(self) -> None:
        """Create the remote repository layout if it does not exist yet."""

    @abstractmethod
    def fetch_lineage(self) -> Optional[Dict]:
        """The remote's lineage payload (``{"nodes": [...]}``), or None."""

    def fetch_lineage_versioned(self) -> Tuple[Optional[Dict], str]:
        """The lineage payload together with its etag (for optimistic swap).

        The default derives the etag locally; transports whose server
        computes it (HTTP ``ETag`` header) override to save the re-hash."""
        payload = self.fetch_lineage()
        return payload, lineage_etag(payload)

    @abstractmethod
    def publish_lineage(self, payload: Dict,
                        expected: Optional[str] = None) -> Optional[Dict]:
        """Atomically replace the remote lineage document (the commit point).

        With ``expected`` set, the replace is conditional: it succeeds only
        while the remote document's etag still equals ``expected`` (compare-
        and-swap), raising :class:`PublishConflict` otherwise. ``None``
        publishes unconditionally (last writer wins — single-writer use).

        Returns the receiver's acknowledgement when it has one — e.g. the
        hub's ``{"etag", "quarantined_rejected"}`` — or ``None``. Callers
        MUST honor ``quarantined_rejected``: those nodes were NOT accepted
        and may not be recorded as common in the merge base."""

    @abstractmethod
    def have(self, keys: Sequence[str]) -> Set[str]:
        """Negotiation: the subset of ``keys`` the remote already stores."""

    @abstractmethod
    def read_objects(self, keys: Sequence[str]) -> Dict[str, bytes]:
        """Fetch a batch of CAS objects by key."""

    def object_sizes(self, keys: Sequence[str]
                     ) -> Optional[Dict[str, int]]:
        """Stored byte size per key, for the keys the remote has.

        Optional capability (default: unknown → None). The pull planner
        uses it to route large objects — chunked tensors' ``c_`` payloads
        above all — through parallel ranged reads instead of one mget
        stream (DESIGN.md §12)."""
        return None

    @abstractmethod
    def write_objects(self, objects: Mapping[str, bytes]) -> None:
        """Store a batch of CAS objects (idempotent per key)."""

    @abstractmethod
    def finalize(self, roots: Sequence[str]) -> None:
        """Post-transfer: rebuild remote refcounts from the given lineage roots."""

    # -- transfer journal (receiver side) -----------------------------------
    @abstractmethod
    def journal_load(self, transfer_id: str) -> Optional[Dict]: ...

    @abstractmethod
    def journal_write(self, transfer_id: str, payload: Dict) -> None: ...

    @abstractmethod
    def journal_clear(self, transfer_id: str) -> None: ...

    @abstractmethod
    def journal_list(self) -> Sequence[str]:
        """Ids of in-flight (or crashed) transfers — fsck surfaces these."""


class LocalTransport(Transport):
    """Filesystem peer: ``url`` is another repo directory on this machine."""

    # Serializes the check-and-replace of publish_lineage per target path so
    # two same-process pushers (threads, tests) get real compare-and-swap
    # semantics; cross-process writers on one directory are out of scope for
    # LocalTransport (that is exactly what the hub daemon is for).
    _publish_locks: Dict[str, threading.Lock] = {}
    _publish_locks_guard = threading.Lock()

    def __init__(self, url: str) -> None:
        self.url = os.path.abspath(url)
        self._store: Optional[ArtifactStore] = None

    # The store opens lazily so constructing a transport (e.g. ``remote add``)
    # has no filesystem side effects on the remote.
    def _open(self) -> ArtifactStore:
        if self._store is None:
            self._store = ArtifactStore(root=self.url)
        return self._store

    def _lineage_path(self) -> str:
        return os.path.join(self.url, "lineage.json")

    def _journal_dir(self) -> str:
        return os.path.join(self.url, "transfers")

    # -- Transport ----------------------------------------------------------
    def ensure_repo(self) -> None:
        os.makedirs(self.url, exist_ok=True)
        self._open()

    def fetch_lineage(self) -> Optional[Dict]:
        if not os.path.exists(self._lineage_path()):
            return None
        with open(self._lineage_path()) as f:
            return json.load(f)

    def _publish_lock(self) -> threading.Lock:
        with self._publish_locks_guard:
            return self._publish_locks.setdefault(self.url, threading.Lock())

    def publish_lineage(self, payload: Dict,
                        expected: Optional[str] = None) -> Optional[Dict]:
        with self._publish_lock():
            if expected is not None:
                current = lineage_etag(self.fetch_lineage())
                if current != expected:
                    raise PublishConflict(current)
            tmp = self._lineage_path() + ".tmp"
            with open(tmp, "w") as f:
                json.dump(payload, f, indent=1)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self._lineage_path())
        # no server-side policy on a filesystem peer: accepted verbatim
        return {"etag": lineage_etag(payload), "quarantined_rejected": []}

    def have(self, keys: Sequence[str]) -> Set[str]:
        cas = self._open().cas
        return {k for k in keys if cas.has(k)}

    def read_objects(self, keys: Sequence[str]) -> Dict[str, bytes]:
        cas = self._open().cas
        return {k: cas.get_bytes(k) for k in keys}

    def object_sizes(self, keys: Sequence[str]) -> Dict[str, int]:
        cas = self._open().cas
        return {k: cas.size(k) for k in keys if cas.has(k)}

    def write_objects(self, objects: Mapping[str, bytes]) -> None:
        store = self._open()
        store.import_objects(objects)

    def finalize(self, roots: Sequence[str]) -> None:
        self._open().rebuild_refcounts(roots)

    # -- journal ------------------------------------------------------------
    def _journal_path(self, transfer_id: str) -> str:
        return os.path.join(self._journal_dir(), f"{transfer_id}.json")

    def journal_load(self, transfer_id: str) -> Optional[Dict]:
        path = self._journal_path(transfer_id)
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)

    def journal_write(self, transfer_id: str, payload: Dict) -> None:
        os.makedirs(self._journal_dir(), exist_ok=True)
        tmp = self._journal_path(transfer_id) + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, self._journal_path(transfer_id))

    def journal_clear(self, transfer_id: str) -> None:
        path = self._journal_path(transfer_id)
        if os.path.exists(path):
            os.remove(path)

    def journal_list(self) -> Sequence[str]:
        if not os.path.isdir(self._journal_dir()):
            return []
        return sorted(f[:-5] for f in os.listdir(self._journal_dir())
                      if f.endswith(".json"))
