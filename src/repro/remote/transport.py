"""Transport — the wire interface between a local repo and a remote peer.

Every method is one protocol round-trip and moves only bytes and keys, never
live objects: ``have`` answers the negotiation (DESIGN.md §8.2),
``read_objects``/``write_objects`` move CAS payloads in batches,
``fetch_lineage``/``publish_lineage`` exchange the graph metadata document,
and the ``journal_*`` trio persists transfer progress on the receiving side
so an interrupted push resumes instead of restarting (§8.4). The interface
maps 1:1 onto HTTP endpoints (``GET /have``, ``POST /objects``, ...) so a
network transport can slot in without touching the sync engine.

:class:`LocalTransport` is the filesystem implementation: the remote is just
another repo directory, opened through its own :class:`ArtifactStore` — which
is also what a server process would do on its side of an HTTP transport.
"""

from __future__ import annotations

import json
import os
from abc import ABC, abstractmethod
from typing import Dict, Mapping, Optional, Sequence, Set

from repro.store.artifact_store import ArtifactStore


class Transport(ABC):
    """Abstract peer repository endpoint."""

    url: str

    @abstractmethod
    def ensure_repo(self) -> None:
        """Create the remote repository layout if it does not exist yet."""

    @abstractmethod
    def fetch_lineage(self) -> Optional[Dict]:
        """The remote's lineage payload (``{"nodes": [...]}``), or None."""

    @abstractmethod
    def publish_lineage(self, payload: Dict) -> None:
        """Atomically replace the remote lineage document (the commit point)."""

    @abstractmethod
    def have(self, keys: Sequence[str]) -> Set[str]:
        """Negotiation: the subset of ``keys`` the remote already stores."""

    @abstractmethod
    def read_objects(self, keys: Sequence[str]) -> Dict[str, bytes]:
        """Fetch a batch of CAS objects by key."""

    @abstractmethod
    def write_objects(self, objects: Mapping[str, bytes]) -> None:
        """Store a batch of CAS objects (idempotent per key)."""

    @abstractmethod
    def finalize(self, roots: Sequence[str]) -> None:
        """Post-transfer: rebuild remote refcounts from the given lineage roots."""

    # -- transfer journal (receiver side) -----------------------------------
    @abstractmethod
    def journal_load(self, transfer_id: str) -> Optional[Dict]: ...

    @abstractmethod
    def journal_write(self, transfer_id: str, payload: Dict) -> None: ...

    @abstractmethod
    def journal_clear(self, transfer_id: str) -> None: ...

    @abstractmethod
    def journal_list(self) -> Sequence[str]:
        """Ids of in-flight (or crashed) transfers — fsck surfaces these."""


class LocalTransport(Transport):
    """Filesystem peer: ``url`` is another repo directory on this machine."""

    def __init__(self, url: str) -> None:
        self.url = os.path.abspath(url)
        self._store: Optional[ArtifactStore] = None

    # The store opens lazily so constructing a transport (e.g. ``remote add``)
    # has no filesystem side effects on the remote.
    def _open(self) -> ArtifactStore:
        if self._store is None:
            self._store = ArtifactStore(root=self.url)
        return self._store

    def _lineage_path(self) -> str:
        return os.path.join(self.url, "lineage.json")

    def _journal_dir(self) -> str:
        return os.path.join(self.url, "transfers")

    # -- Transport ----------------------------------------------------------
    def ensure_repo(self) -> None:
        os.makedirs(self.url, exist_ok=True)
        self._open()

    def fetch_lineage(self) -> Optional[Dict]:
        if not os.path.exists(self._lineage_path()):
            return None
        with open(self._lineage_path()) as f:
            return json.load(f)

    def publish_lineage(self, payload: Dict) -> None:
        tmp = self._lineage_path() + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._lineage_path())

    def have(self, keys: Sequence[str]) -> Set[str]:
        cas = self._open().cas
        return {k for k in keys if cas.has(k)}

    def read_objects(self, keys: Sequence[str]) -> Dict[str, bytes]:
        cas = self._open().cas
        return {k: cas.get_bytes(k) for k in keys}

    def write_objects(self, objects: Mapping[str, bytes]) -> None:
        store = self._open()
        store.import_objects(objects)

    def finalize(self, roots: Sequence[str]) -> None:
        self._open().rebuild_refcounts(roots)

    # -- journal ------------------------------------------------------------
    def _journal_path(self, transfer_id: str) -> str:
        return os.path.join(self._journal_dir(), f"{transfer_id}.json")

    def journal_load(self, transfer_id: str) -> Optional[Dict]:
        path = self._journal_path(transfer_id)
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)

    def journal_write(self, transfer_id: str, payload: Dict) -> None:
        os.makedirs(self._journal_dir(), exist_ok=True)
        tmp = self._journal_path(transfer_id) + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, self._journal_path(transfer_id))

    def journal_clear(self, transfer_id: str) -> None:
        path = self._journal_path(transfer_id)
        if os.path.exists(path):
            os.remove(path)

    def journal_list(self) -> Sequence[str]:
        if not os.path.isdir(self._journal_dir()):
            return []
        return sorted(f[:-5] for f in os.listdir(self._journal_dir())
                      if f.endswith(".json"))
