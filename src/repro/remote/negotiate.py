"""Have/want object negotiation over manifest closures (DESIGN.md §8.2).

A lineage subgraph is shipped as the *closure* of its manifests: every
manifest, every full-tensor and delta-blob object its entries reference, and
— because delta entries reconstruct against ``(parent_ref, parent_key)`` —
every chain-parent manifest, transitively. The closure traversal itself
lives in :mod:`repro.store.manifest_walk` (shared with the store's refcount
replay and fsck); this module layers the sync-protocol decisions on top.

:func:`plan_transfer` subtracts what the receiver advertised via ``have``
and fixes the deterministic transfer order — data before metadata
(blobs/tensors first, then manifests shallow-chain-first), so an
interrupted transfer never leaves a manifest on the receiver whose payload
objects are guaranteed absent. The *full* ordered closure (``plan.order``)
is what the resumable journal chunks over: it is identical across attempts,
so chunk ids recorded before a crash match on retry (DESIGN.md §8.4).

Delta-chain awareness lives in :func:`chain_refs` + :func:`needs_flatten`:
a filtered (shallow) push prefers shipping delta blobs when the receiver
already has — or is about to receive — the chain base, and falls back to
flattening the manifest to full tensors when the base lies outside the
selection (§8.3).

Keys negotiated here are the CAS schemes of DESIGN.md §3.2: ``m_`` manifest
hashes, bare tensor/blob content hashes, ``c_`` chunk objects, and (when
diagnostics ride along) ``t_`` ledger entries. The derived ``s_`` scoped-
content keys never appear in a closure — they name no stored object. All
object payloads are the *stored* (delta-quantized) artifact form; nothing
in-memory is negotiated.

Chunked entries (DESIGN.md §12) make have/want *chunk-granular* with no
new protocol: ``parse_manifest`` lists each raw-chunk ``c_`` key and
per-chunk delta blob as a closure object, so a receiver that already holds
most of a multi-GB tensor — from an earlier version sharing its grid —
advertises those chunks in ``have`` and only the edited ones cross the
wire. :func:`partition_by_size` is the planner's other half: splitting a
want-set at a byte floor lets the transfer engine route the few huge
objects through segmented parallel range reads while everything else rides
the batched mget stream.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Sequence, Set, Tuple

from repro.store.manifest_walk import (Fetch, ManifestInfo, closure_keys,
                                       parse_manifest, walk_manifests)

__all__ = [
    "Fetch", "ManifestInfo", "parse_manifest", "walk_manifests",
    "closure_keys", "chunked", "partition_by_size", "chain_refs",
    "needs_flatten", "TransferPlan", "plan_transfer", "CHUNK_OBJECTS",
]

#: objects fetched per negotiation/transfer batch
CHUNK_OBJECTS = 32


def chunked(seq: Sequence[str], n: int = CHUNK_OBJECTS) -> Iterable[List[str]]:
    seq = list(seq)
    for i in range(0, len(seq), n):
        yield seq[i:i + n]


def partition_by_size(keys: Sequence[str], sizes: Mapping[str, int],
                      floor: int) -> Tuple[List[str], List[str]]:
    """Split ``keys`` into ``(small, large)`` at ``floor`` stored bytes.

    Keys with unknown size (absent from ``sizes`` — e.g. the peer predates
    the sizes endpoint) count as small: the mget stream is always correct,
    ranged parallelism is only an optimization. Both halves preserve the
    deterministic plan order."""
    small = [k for k in keys if sizes.get(k, 0) < floor]
    large = [k for k in keys if sizes.get(k, 0) >= floor]
    return small, large


def chain_refs(closure: Dict[str, ManifestInfo], ref: str) -> List[str]:
    """The delta chain above ``ref``: its parent manifests, transitively."""
    out: List[str] = []
    frontier = list(closure[ref].parents)
    seen: Set[str] = set()
    while frontier:
        p = frontier.pop()
        if p in seen:
            continue
        seen.add(p)
        out.append(p)
        if p in closure:
            frontier.extend(closure[p].parents)
    return out


def needs_flatten(closure: Dict[str, ManifestInfo], ref: str,
                  shipped: Set[str], receiver_has: Set[str]) -> bool:
    """True when ``ref``'s delta chain cannot reconstruct on the receiver.

    Ship the delta form when every chain parent is either part of the
    selection (``shipped``) or already on the receiver; otherwise the caller
    must flatten ``ref`` to full tensors (the shallow-push fallback)."""
    return any(p not in shipped and p not in receiver_has
               for p in chain_refs(closure, ref))


@dataclasses.dataclass
class TransferPlan:
    """Negotiated transfer: what to send, in which deterministic order."""

    order: List[str]            # FULL closure in transfer order (stable)
    wants: List[str]            # the subset missing on the receiver
    total: int                  # closure size (for dedup-ratio reporting)

    @property
    def transferred(self) -> int:
        return len(self.wants)

    @property
    def dedup_ratio(self) -> float:
        """Fraction of the closure the negotiation avoided sending."""
        if self.total == 0:
            return 1.0
        return 1.0 - len(self.wants) / self.total


def plan_transfer(closure: Dict[str, ManifestInfo],
                  have: Set[str]) -> TransferPlan:
    """Fix the transfer order and subtract the receiver's ``have`` set.

    Data objects ship before manifests, manifests shallow-chain-first — so a
    crash mid-transfer can strand data objects (harmless: content-addressed,
    refcount-rebuilt later) but never a manifest whose chain is knowably
    incomplete *behind* it in the stream. The order is a pure function of
    the closure, NOT of ``have``, so resumed attempts chunk identically."""
    keys = closure_keys(closure)
    data = sorted(k for k in keys if k not in closure)
    manifests = sorted(closure, key=lambda r: (closure[r].depth, r))
    order = data + manifests
    have = set(have)
    return TransferPlan(order=order,
                        wants=[k for k in order if k not in have],
                        total=len(keys))
