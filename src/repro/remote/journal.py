"""Resumable transfer journal (DESIGN.md §8.4).

A transfer is split into fixed-size chunks of CAS keys, taken over the
*full* negotiated closure in its deterministic transfer order — so chunk
boundaries and ids are identical across attempts. The *receiving* side
persists a journal document ``{"done": [chunk_id...], "total": N}`` after
every completed chunk; the transfer id is a content hash of the closure, so
a resumed push/pull maps onto the same journal, inherits its progress
record, and retires it on completion.

Because every object is content-addressed, the journal is a *progress* and
*diagnosis* structure, not a correctness one: skipping is decided by the
have/want negotiation (the receiver's actual contents), done markers only
corroborate it, and a crashed transfer leaves only idempotently
re-writable objects plus a journal file that ``fsck`` reports as an
in-flight transfer. Consistency comes from ordering — the lineage document
publishes only after the last chunk lands and is the single commit point
of a sync.

:class:`LocalJournalStore` persists journals for the pull direction (where
the receiver is the local repo); for push the journal methods live on the
:class:`~repro.remote.transport.Transport` — over HTTP they become the
hub's ``/api/journal`` endpoints (DESIGN.md §11.4), so an interrupted
network push resumes against the same closure-keyed journal id exactly
like a local one. Chunks carry stored CAS objects only (``m_``/tensor/
blob/``t_`` keys, §3.2) — journal state never references live models.
"""

from __future__ import annotations

import concurrent.futures as cf
import json
import os
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.common.hashing import bytes_hash
from repro.obs import propagate, span
from repro.remote.negotiate import chunked

#: parallel chunk workers per transfer
TRANSFER_WORKERS = 4


def transfer_id(keys: Sequence[str], direction: str) -> str:
    """Stable id for a transfer. Key it on the *closure* (the full negotiated
    object set), not the want-list: a resumed attempt has a smaller want-list
    (objects that landed before the crash negotiate away) but must map onto
    the same journal to inherit and eventually clear it."""
    return bytes_hash(("\n".join(sorted(keys)) + "|" + direction).encode())[:16]


def chunk_id(keys: Sequence[str]) -> str:
    return bytes_hash("\n".join(keys).encode())[:16]


class LocalJournalStore:
    """Journal persistence in a local repo (``<repo>/transfers/``)."""

    def __init__(self, root: str) -> None:
        self.root = os.path.join(root, "transfers")

    def _path(self, tid: str) -> str:
        return os.path.join(self.root, f"{tid}.json")

    def journal_load(self, tid: str) -> Optional[Dict]:
        if not os.path.exists(self._path(tid)):
            return None
        with open(self._path(tid)) as f:
            return json.load(f)

    def journal_write(self, tid: str, payload: Dict) -> None:
        os.makedirs(self.root, exist_ok=True)
        tmp = self._path(tid) + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, self._path(tid))

    def journal_clear(self, tid: str) -> None:
        if os.path.exists(self._path(tid)):
            os.remove(self._path(tid))

    def journal_list(self) -> List[str]:
        if not os.path.isdir(self.root):
            return []
        return sorted(f[:-5] for f in os.listdir(self.root)
                      if f.endswith(".json"))


def run_journalled_transfer(journal_store, tid: str, order: Sequence[str],
                            wants: Sequence[str], direction: str,
                            move_chunk: Callable[[List[str]], int],
                            chunk_size: int,
                            workers: int = TRANSFER_WORKERS,
                            ) -> Tuple[int, int, int]:
    """Move ``wants`` in parallel journalled chunks under journal id ``tid``.

    Chunk boundaries and ids are taken over ``order`` — the FULL negotiated
    closure in its deterministic transfer order — not over ``wants``: a
    resumed attempt has a smaller want-list (landed objects negotiate away),
    but identical chunking, so chunk ids recorded before a crash still match
    and those chunks are skipped without touching the wire. Within a chunk,
    only the keys still in ``wants`` move.

    The want-list stays authoritative over the journal: a chunk whose keys
    the receiver still misses is (re-)moved even if marked done — a journal
    can go stale (receiver gc, tampering), and skipping on its word alone
    would lose data. A done marker earns ``chunks_resumed`` credit only when
    the negotiation confirms its objects all landed.

    ``move_chunk(keys) -> bytes_moved`` performs one batch in either
    direction. Chunks run on a thread pool; the journal is updated from the
    coordinating thread after each completion (no concurrent journal writes).
    Returns ``(objects_moved, bytes_moved, chunks_resumed)``;
    ``chunks_resumed`` objects moved in an earlier attempt and are NOT
    re-counted."""
    want_set = set(wants)
    if not want_set:
        # nothing to move — but a journal left by a crashed attempt whose
        # objects all landed is now complete: retire it
        journal_store.journal_clear(tid)
        return 0, 0, 0
    journal = journal_store.journal_load(tid) or {"done": [], "total": 0}
    done = set(journal.get("done", []))
    pending = []
    resumed = 0
    for c in chunked(order, chunk_size):
        cid = chunk_id(c)
        keys = [k for k in c if k in want_set]
        if keys:
            pending.append((cid, keys))
        elif cid in done:
            resumed += 1
    moved_objects = 0
    moved_bytes = 0
    first_error: Optional[BaseException] = None

    def traced_move(cid, keys):
        with span("journal.chunk", cat="remote", chunk=cid,
                  objects=len(keys)):
            return move_chunk(keys)

    # propagate(): worker threads never saw the caller's contextvars, so
    # without the wrap the per-chunk spans would float parentless instead
    # of nesting under the surrounding push/pull transfer span
    moved = propagate(traced_move)
    with cf.ThreadPoolExecutor(max_workers=max(1, workers)) as ex:
        futures = {ex.submit(moved, cid, keys): (cid, keys)
                   for cid, keys in pending}
        for fut in cf.as_completed(futures):
            cid, keys = futures[fut]
            try:
                moved_bytes += fut.result()
            except BaseException as exc:
                # Keep draining: chunks that DID land must reach the journal
                # so the resumed transfer skips them.
                first_error = first_error or exc
                continue
            moved_objects += len(keys)
            done.add(cid)
            journal_store.journal_write(
                tid, {"done": sorted(done), "total": resumed + len(pending),
                      "direction": direction})
    if first_error is not None:
        raise first_error
    journal_store.journal_clear(tid)
    return moved_objects, moved_bytes, resumed
