"""Push/pull/clone of lineage subgraphs (DESIGN.md §8).

The sync engine drives a :class:`~repro.remote.transport.Transport` through
the protocol phases:

1. **select** — nodes to ship, all or an ``fnmatch`` filter (``name@v*``);
2. **negotiate** — walk the manifest closure (:mod:`repro.remote.negotiate`),
   ask the receiver what it already ``have``s, and plan the difference.
   Delta entries ship as blobs when the receiver has (or is receiving) the
   chain base; a shallow push whose chain base falls outside the selection
   flattens that manifest to full tensors instead (§8.3);
3. **transfer** — parallel chunked object movement with a resumable journal
   on the receiving side (:mod:`repro.remote.journal`);
4. **reconcile** — a three-way merge of lineage metadata against the
   remote-tracking base state, reusing the paper-§5 conflict classification
   (``conflict`` / ``possible_conflict`` / ``no_conflict``) per node, with
   artifact-level auto-merge of divergent models on pull;
5. **publish** — the merged lineage document replaces the receiver's
   atomically via *optimistic swap* (DESIGN.md §11.3): the publish carries
   the etag of the document the merge was based on, a concurrent pusher
   makes the swap fail (HTTP 409), and the engine re-fetches/re-merges/
   retries. After publish, refcounts are rebuilt from the lineage roots.

The engine is transport-agnostic: ``LocalTransport`` (a directory) and
:class:`repro.remote.http.HttpTransport` (a hub daemon, §11) both satisfy
the same ABC, so push/pull/clone against ``http://`` remotes are the same
code path, byte for byte. Bit-identity across peers always means the
*stored* artifacts (store-loaded params) — in-memory models differ from
their committed form by the delta-quantization eps.

An interrupted transfer leaves both sides consistent: the receiver gains
only content-addressed objects (no lineage pointer moves) plus a journal
file, and the next push/pull of the same want-set resumes from the journal.
"""

from __future__ import annotations

import dataclasses
import fnmatch
import json
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.lineage import LineageGraph
from repro.obs import span
from repro.core.merge import (CONFLICT, NO_CONFLICT, POSSIBLE_CONFLICT,
                              merge_artifacts)
from repro.remote.journal import (LocalJournalStore, run_journalled_transfer,
                                  transfer_id)
from repro.remote.negotiate import (CHUNK_OBJECTS, closure_keys, needs_flatten,
                                    partition_by_size, plan_transfer,
                                    walk_manifests)
from repro.remote.transport import (LocalTransport, PublishConflict,
                                    Transport)

_SEVERITY = {NO_CONFLICT: 0, POSSIBLE_CONFLICT: 1, CONFLICT: 2}

#: bound on the 409 -> re-fetch -> re-merge -> re-publish loop of a push;
#: each retry merges against a strictly newer remote document, so livelock
#: needs a pathological writer hammering the remote faster than we merge
MAX_PUBLISH_ATTEMPTS = 6

#: stored objects at/above this size are fetched as segmented parallel
#: ranged GETs instead of riding the single mget stream; below it the
#: per-request overhead of extra connections outweighs the overlap
RANGE_FLOOR = 4 * 2 ** 20
RANGE_PART = 1 * 2 ** 20
RANGE_WORKERS = 4


def fetch_objects(transport: Transport,
                  keys: Sequence[str]) -> Dict[str, bytes]:
    """Size-aware batch fetch: big objects ride parallel ranged reads.

    Asks the transport for stored sizes first (an optional capability —
    :class:`LocalTransport` answers from the CAS, the hub via
    ``POST /api/objects/sizes``, older peers return nothing) and routes
    every object at/above :data:`RANGE_FLOOR` — in practice chunked
    tensors' ``c_`` payloads — through ``read_object_parallel``; the rest
    move as one mget stream exactly as before. Content addressing verifies
    each reassembled payload when it is imported, so a torn ranged read can
    never land silently."""
    keys = list(keys)
    ranged = getattr(transport, "read_object_parallel", None)
    if ranged is None or not keys:
        return transport.read_objects(keys)
    sizes = transport.object_sizes(keys) or {}
    small, large = partition_by_size(keys, sizes, RANGE_FLOOR)
    out = {k: ranged(k, sizes[k], part_bytes=RANGE_PART,
                     workers=RANGE_WORKERS) for k in large}
    if small:
        out.update(transport.read_objects(small))
    return out


def _is_url(s: str) -> bool:
    return "://" in s


# ---------------------------------------------------------------------------
# Remote configuration + tracking state
# ---------------------------------------------------------------------------


def _remotes_path(repo: str) -> str:
    return os.path.join(repo, "remotes.json")


def remote_list(repo: str) -> Dict[str, str]:
    path = _remotes_path(repo)
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f)

def _save_remotes(repo: str, remotes: Dict[str, str]) -> None:
    os.makedirs(repo, exist_ok=True)
    tmp = _remotes_path(repo) + ".tmp"
    with open(tmp, "w") as f:
        json.dump(remotes, f, indent=1)
    os.replace(tmp, _remotes_path(repo))


def remote_add(repo: str, name: str, url: str) -> None:
    remotes = remote_list(repo)
    # Directory remotes normalize to absolute paths (stable across cwd
    # changes); http(s) hub urls pass through untouched.
    remotes[name] = url if _is_url(url) else os.path.abspath(url)
    _save_remotes(repo, remotes)


def remote_remove(repo: str, name: str) -> None:
    remotes = remote_list(repo)
    remotes.pop(name, None)
    _save_remotes(repo, remotes)


def _transport_for(url: str) -> Transport:
    """Scheme dispatch: ``http(s)://`` speaks to a hub daemon
    (:class:`~repro.remote.http.HttpTransport`), anything else is a
    filesystem peer."""
    if _is_url(url):
        from repro.remote.http import HttpTransport  # lazy: client-only dep
        return HttpTransport(url)
    return LocalTransport(url)


def resolve_transport(repo: str, name_or_url: str
                      ) -> Tuple[Transport, Optional[str]]:
    """A configured remote name resolves through ``remotes.json`` (and gets
    tracking state); a bare path or ``http(s)://`` url is used directly
    (stateless sync); an already-constructed :class:`Transport` (e.g. a
    :class:`~repro.hub.replica.ReplicaSetTransport`) passes through."""
    if isinstance(name_or_url, Transport):
        return name_or_url, None
    remotes = remote_list(repo)
    if name_or_url in remotes:
        return _transport_for(remotes[name_or_url]), name_or_url
    return _transport_for(name_or_url), None


class RemoteState:
    """Remote-tracking state: the merge base for the next sync.

    MGit's analogue of git's remote-tracking refs. The stored document holds
    only *common* nodes — ones both sides have agreed on during a previous
    push or pull — never remote nodes that were merely seen but not
    integrated (those must merge as additions, not read as local deletions).
    ``name=None`` (syncing to a bare path) disables tracking: the base
    degrades to the empty graph and divergence classifies conservatively."""

    def __init__(self, repo: Optional[str], name: Optional[str]) -> None:
        self.path = (os.path.join(repo, "remotes", f"{name}.state.json")
                     if repo and name else None)

    def load(self) -> Optional[Dict]:
        if self.path is None or not os.path.exists(self.path):
            return None
        with open(self.path) as f:
            return json.load(f)

    def save(self, payload: Dict) -> None:
        if self.path is None:
            return
        os.makedirs(os.path.dirname(self.path), exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, self.path)


# ---------------------------------------------------------------------------
# Three-way lineage-metadata merge
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class NodeMergeOutcome:
    name: str
    status: str                 # merge.py conflict class
    detail: str = ""


@dataclasses.dataclass
class LineageMergeReport:
    status: str                 # worst per-node status
    outcomes: List[NodeMergeOutcome]

    @property
    def conflicts(self) -> List[str]:
        return [o.name for o in self.outcomes if o.status == CONFLICT]

    def to_json(self) -> Dict[str, Any]:
        return {"status": self.status,
                "outcomes": [dataclasses.asdict(o) for o in self.outcomes
                             if o.status != NO_CONFLICT],
                "conflicts": self.conflicts}


def _merge_list(base: List[str], ours: List[str],
                theirs: List[str]) -> List[str]:
    """Three-way merge of an (ordered) name list; deletions propagate."""
    removed = (set(base) - set(ours)) | (set(base) - set(theirs))
    out = [x for x in ours if x not in removed]
    out += [x for x in theirs if x not in set(ours) and x not in removed]
    return out


def _merge_scalar(base, ours, theirs) -> Tuple[Any, bool]:
    """Returns (merged value, both-sides-changed-divergently)."""
    if ours == theirs:
        return ours, False
    if ours == base:
        return theirs, False
    if theirs == base:
        return ours, False
    return ours, True


def _classify_artifact_divergence(store, name: str, base_ref: Optional[str],
                                  ours_ref: str, theirs_ref: str
                                  ) -> Tuple[Optional[str], str, str]:
    """Both sides re-committed a node's model: classify with the paper-§5 decision
    tree (Figure 2) and auto-merge parameters when it allows. Returns
    ``(ref_to_use or None-for-keep-ours, status, detail)``."""
    if store is None or base_ref is None:
        return None, CONFLICT, "divergent model with no common base version"
    try:
        ancestor = store.load_artifact(base_ref)
        ours = store.load_artifact(ours_ref)
        theirs = store.load_artifact(theirs_ref)
        result = merge_artifacts(ancestor, ours, theirs)
    except Exception as exc:  # missing objects, shape drift, ...
        return None, CONFLICT, f"could not classify divergence: {exc}"
    if result.status == CONFLICT or result.merged is None:
        return None, CONFLICT, f"parameter merge conflict: {result.detail}"
    merged_ref = store.commit_artifact(name, result.merged,
                                       parent_ref=ours_ref)
    return merged_ref, result.status, f"auto-merged models: {result.detail}"


def _merge_node(name: str, base: Optional[Dict], ours: Optional[Dict],
                theirs: Optional[Dict], store=None
                ) -> Tuple[Optional[Dict], NodeMergeOutcome]:
    """Merge one node's JSON document; None means the node is deleted."""
    if ours is None and theirs is None:
        return None, NodeMergeOutcome(name, NO_CONFLICT, "deleted both sides")
    if ours is None:
        if base is not None and base == theirs:
            return None, NodeMergeOutcome(name, NO_CONFLICT,
                                          "deleted locally")
        if base is None:
            return dict(theirs), NodeMergeOutcome(name, NO_CONFLICT,
                                                  "new from remote")
        return dict(theirs), NodeMergeOutcome(
            name, POSSIBLE_CONFLICT,
            "deleted locally but changed remotely — restored")
    if theirs is None:
        if base is not None and base == ours:
            return None, NodeMergeOutcome(name, NO_CONFLICT,
                                          "deleted remotely")
        if base is None:
            return dict(ours), NodeMergeOutcome(name, NO_CONFLICT,
                                                "local-only node")
        return dict(ours), NodeMergeOutcome(
            name, POSSIBLE_CONFLICT,
            "deleted remotely but changed locally — kept")

    base = base or {}
    merged = dict(ours)
    status, details = NO_CONFLICT, []

    for field in ("parents", "children", "version_parents",
                  "version_children"):
        merged[field] = _merge_list(base.get(field, []), ours.get(field, []),
                                    theirs.get(field, []))

    meta = dict(theirs.get("metadata", {}))
    base_meta = base.get("metadata", {})
    for k, v in ours.get("metadata", {}).items():
        mv, diverged = _merge_scalar(base_meta.get(k), v,
                                     meta.get(k, base_meta.get(k)))
        meta[k] = mv
        if diverged:
            status = max(status, POSSIBLE_CONFLICT, key=_SEVERITY.get)
            details.append(f"metadata key {k!r} diverged (kept local)")
    merged["metadata"] = meta

    for field, on_diverge in (("model_type", CONFLICT),
                              ("creation_fn", POSSIBLE_CONFLICT)):
        value, diverged = _merge_scalar(base.get(field), ours.get(field),
                                        theirs.get(field))
        merged[field] = value
        if diverged:
            status = max(status, on_diverge, key=_SEVERITY.get)
            details.append(f"{field} diverged (kept local)")

    ref, diverged = _merge_scalar(base.get("artifact_ref"),
                                  ours.get("artifact_ref"),
                                  theirs.get("artifact_ref"))
    if diverged:
        new_ref, art_status, detail = _classify_artifact_divergence(
            store, name, base.get("artifact_ref"), ours["artifact_ref"],
            theirs["artifact_ref"])
        ref = new_ref if new_ref is not None else ours.get("artifact_ref")
        status = max(status, art_status, key=_SEVERITY.get)
        details.append(detail)
    merged["artifact_ref"] = ref

    return merged, NodeMergeOutcome(name, status, "; ".join(details))


def merge_lineage(base_payload: Optional[Dict], ours_payload: Dict,
                  theirs_payload: Dict, store=None
                  ) -> Tuple[Dict, LineageMergeReport]:
    """Three-way merge of two lineage documents against a common base.

    Grow-only reconciliation by default: concurrently added nodes and edges
    union; divergent per-node fields classify through the paper-§5 conflict
    classes, keeping the local side on ``conflict``. Adjacency lists are
    pruned to the merged node set, so a filtered (shallow) payload never
    introduces dangling references."""
    def index(payload: Optional[Dict]) -> Dict[str, Dict]:
        return {n["name"]: n for n in (payload or {}).get("nodes", [])}

    base_nodes, ours_nodes, theirs_nodes = (
        index(base_payload), index(ours_payload), index(theirs_payload))
    merged_nodes: Dict[str, Dict] = {}
    outcomes: List[NodeMergeOutcome] = []
    for name in list(ours_nodes) + [n for n in theirs_nodes
                                    if n not in ours_nodes]:
        node, outcome = _merge_node(name, base_nodes.get(name),
                                    ours_nodes.get(name),
                                    theirs_nodes.get(name), store=store)
        if node is not None:
            merged_nodes[name] = node
        outcomes.append(outcome)
    for node in merged_nodes.values():
        for field in ("parents", "children", "version_parents",
                      "version_children"):
            node[field] = [x for x in node.get(field, [])
                           if x in merged_nodes]
    status = max((o.status for o in outcomes), default=NO_CONFLICT,
                 key=_SEVERITY.get)
    return ({"nodes": list(merged_nodes.values())},
            LineageMergeReport(status=status, outcomes=outcomes))


# ---------------------------------------------------------------------------
# Sync operations
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SyncReport:
    direction: str
    selected_nodes: List[str]
    objects_total: int          # closure size after negotiation planning
    objects_transferred: int
    bytes_transferred: int
    chunks_resumed: int = 0
    publish_retries: int = 0    # optimistic-swap 409s absorbed (DESIGN.md §11.3)
    flattened: Dict[str, str] = dataclasses.field(default_factory=dict)
    quarantined_skipped: List[str] = dataclasses.field(default_factory=list)
    # nodes the RECEIVER's quarantine policy refused at publish (§11.3) —
    # distinct from quarantined_skipped, which the sender filtered itself
    quarantine_rejected_by_remote: List[str] = dataclasses.field(
        default_factory=list)
    merge: Optional[LineageMergeReport] = None
    published: bool = True
    # transport-level reliability (ISSUE 8): a push that limped through
    # 5xx storms or connection resets says so instead of looking clean.
    # Per-endpoint-family dicts come from HttpTransport.retry_stats()
    # deltas over this one sync; LocalTransport syncs report zeros.
    transport_retries: int = 0
    transport_backoff_s: float = 0.0
    transport_terminal_failures: int = 0
    transport_retries_by_family: Dict[str, int] = dataclasses.field(
        default_factory=dict)

    @property
    def dedup_ratio(self) -> float:
        if self.objects_total == 0:
            return 1.0
        return 1.0 - self.objects_transferred / self.objects_total

    def to_json(self) -> Dict[str, Any]:
        out = dataclasses.asdict(self)
        out.pop("merge", None)
        out["dedup_ratio"] = round(self.dedup_ratio, 4)
        if self.merge is not None:
            out["merge"] = self.merge.to_json()
        return out


def _select_nodes(payload: Dict, filter: Optional[str]) -> List[Dict]:
    nodes = payload.get("nodes", [])
    if filter is None:
        return nodes
    return [n for n in nodes if fnmatch.fnmatch(n["name"], filter)]


def _scoped(payload: Optional[Dict], filter: Optional[str]) -> Optional[Dict]:
    """Restrict a merge base to the filter scope: a shallow sync must not
    interpret out-of-scope base nodes as deletions on either side."""
    if payload is None or filter is None:
        return payload
    return {"nodes": [n for n in payload.get("nodes", [])
                      if fnmatch.fnmatch(n["name"], filter)]}


def _local_fetch(store):
    def fetch(keys: Sequence[str]) -> Dict[str, bytes]:
        return {k: store.cas.get_bytes(k) for k in keys}
    return fetch


def _extra_first(extra: Dict[str, bytes], store):
    """Reader that serves transient (uncommitted) objects before the CAS."""
    def fetch(keys: Sequence[str]) -> Dict[str, bytes]:
        return {k: extra[k] if k in extra else store.cas.get_bytes(k)
                for k in keys}
    return fetch


class _ImportingFetch:
    """Local-first fetch for pull planning that KEEPS what it pulls.

    Manifests read over the wire during closure negotiation are imported
    into the local store immediately (content-addressed, idempotent), so the
    journalled transfer doesn't fetch the same payloads a second time. The
    counters feed the sync report — these bytes did cross the wire."""

    def __init__(self, store, transport: Transport) -> None:
        self.store = store
        self.transport = transport
        self.imported = 0
        self.imported_bytes = 0

    def __call__(self, keys: Sequence[str]) -> Dict[str, bytes]:
        out, missing = {}, []
        for k in keys:
            if self.store.cas.has(k):
                out[k] = self.store.cas.get_bytes(k)
            else:
                missing.append(k)
        if missing:
            fetched = self.transport.read_objects(missing)
            self.store.import_objects(fetched)
            self.imported += len(fetched)
            self.imported_bytes += sum(len(v) for v in fetched.values())
            out.update(fetched)
        return out


def _retry_snapshot(transport) -> Optional[Dict[str, Any]]:
    fn = getattr(transport, "retry_stats", None)
    return fn() if callable(fn) else None


def _retry_delta(transport, before: Optional[Dict[str, Any]]
                 ) -> Dict[str, Any]:
    """What this sync's transport retried, as SyncReport field values."""
    after = _retry_snapshot(transport)
    if after is None or before is None:
        return {}
    by_family = {
        fam: n - before["retries"].get(fam, 0)
        for fam, n in after["retries"].items()
        if n - before["retries"].get(fam, 0) > 0}
    backoff = (sum(after["backoff_s"].values())
               - sum(before["backoff_s"].values()))
    terminal = (sum(after["terminal_failures"].values())
                - sum(before["terminal_failures"].values()))
    return {"transport_retries": sum(by_family.values()),
            "transport_backoff_s": round(max(backoff, 0.0), 3),
            "transport_terminal_failures": max(terminal, 0),
            "transport_retries_by_family": by_family}


def push(graph: LineageGraph, transport: Transport,
         filter: Optional[str] = None, state: Optional[RemoteState] = None,
         force: bool = False, chunk_size: int = CHUNK_OBJECTS,
         include_quarantined: bool = False) -> SyncReport:
    """Ship the (filtered) lineage subgraph to the remote.

    Phases: select -> negotiate (closure - remote have) -> journalled
    parallel transfer -> three-way merge into the remote lineage -> atomic
    publish + remote refcount rebuild. A lineage-level conflict aborts before
    publish (like a non-fast-forward push) unless ``force``.

    Nodes a test gate quarantined (DESIGN.md §9.4) are excluded from the
    selection unless ``include_quarantined`` — a regressing model version
    must not propagate to collaborators by default. Their manifests still
    ship as storage-only chain dependencies when a pushed descendant's
    delta chain needs them, so everything sent reconstructs."""
    with span("sync.push", cat="remote"):
        return _push(graph, transport, filter, state, force, chunk_size,
                     include_quarantined)


def _push(graph: LineageGraph, transport: Transport,
          filter: Optional[str], state: Optional[RemoteState],
          force: bool, chunk_size: int,
          include_quarantined: bool) -> SyncReport:
    store = graph.store
    if store is None:
        raise ValueError("push requires a store-backed lineage graph")
    state = state or RemoteState(None, None)
    retry_before = _retry_snapshot(transport)
    transport.ensure_repo()

    ours_payload = graph.to_payload()
    selected = _select_nodes(ours_payload, filter)
    quarantined_skipped: List[str] = []
    if not include_quarantined:
        from repro.core.quarantine import is_quarantined
        quarantined_skipped = [n["name"] for n in selected
                               if is_quarantined(n)]
        selected = [n for n in selected if not is_quarantined(n)]
    refs = [n["artifact_ref"] for n in selected if n.get("artifact_ref")]
    closure = walk_manifests(_local_fetch(store), refs)

    with span("sync.negotiate", cat="remote", keys=len(closure)):
        remote_have = transport.have(sorted(closure_keys(closure)))

    # Shallow push: flatten manifests whose delta chain leaves the selection
    # AND is absent on the receiver; prefer the delta form otherwise. The
    # flattened manifests + tensors are built transiently (never committed
    # into the sender's store) and ride to the wire via ``extra_objects``.
    flattened: Dict[str, str] = {}
    extra_objects: Dict[str, bytes] = {}
    if filter is not None and refs:
        selected_refs = set(refs)
        for node in selected:
            ref = node.get("artifact_ref")
            if not ref or ref in remote_have:
                continue
            if needs_flatten(closure, ref, selected_refs, remote_have):
                flat_ref, objs = store.export_flat_manifest(
                    ref, name=node["name"])
                flattened[ref] = flat_ref
                extra_objects.update(objs)
                node["artifact_ref"] = flat_ref
        if flattened:
            refs = [n["artifact_ref"] for n in selected
                    if n.get("artifact_ref")]
            closure = walk_manifests(_extra_first(extra_objects, store), refs)
            with span("sync.negotiate", cat="remote", keys=len(closure),
                      reason="post-flatten"):
                remote_have = transport.have(sorted(closure_keys(closure)))

    plan = plan_transfer(closure, remote_have)
    read_local = _extra_first(extra_objects, store)

    def move_chunk(keys: List[str]) -> int:
        objs = read_local(keys)
        transport.write_objects(objs)
        return sum(len(v) for v in objs.values())

    tid = transfer_id(plan.order, "push")
    with span("sync.transfer", cat="remote", direction="push",
              objects=len(plan.wants)):
        moved, moved_bytes, resumed = run_journalled_transfer(
            transport, tid, plan.order, plan.wants, "push", move_chunk,
            chunk_size)

    theirs_payload = {"nodes": selected}
    # Roles from the REMOTE's point of view: its document is "ours", the
    # pushed subgraph is "theirs". No artifact auto-merge on push — the
    # remote side cannot be mutated beyond publish (classification only).
    # Quarantined nodes are scoped OUT of the merge base exactly like
    # filtered ones: a node pushed earlier and quarantined since must read
    # as "not part of this sync", never as a local deletion — otherwise the
    # push would silently delete it from the remote document.
    base_payload = _scoped(state.load(), filter)
    if quarantined_skipped and base_payload is not None:
        skip = set(quarantined_skipped)
        base_payload = {"nodes": [n for n in base_payload["nodes"]
                                  if n["name"] not in skip]}
    # Optimistic lineage swap (DESIGN.md §11.3): publish conditionally on
    # the etag of the document this merge was computed against. A racing
    # pusher landing in between makes the swap fail (409 over HTTP) —
    # re-fetch the now-newer document, re-merge, retry. Object uploads are
    # NOT repeated: they are content-addressed and already on the remote.
    publish_retries = 0
    published = False
    server_rejected: List[str] = []
    for _attempt in range(MAX_PUBLISH_ATTEMPTS):
        remote_payload, remote_etag = transport.fetch_lineage_versioned()
        remote_payload = remote_payload or {"nodes": []}
        merged, report = merge_lineage(base_payload, remote_payload,
                                       theirs_payload, store=None)
        published = force or report.status != CONFLICT
        if not published:
            break
        if force and report.status == CONFLICT:
            merged_nodes = {n["name"]: n for n in merged["nodes"]}
            for node in selected:
                merged_nodes[node["name"]] = node
            merged = {"nodes": list(merged_nodes.values())}
        try:
            with span("sync.publish", cat="remote"):
                ack = transport.publish_lineage(merged,
                                                expected=remote_etag)
        except PublishConflict:
            publish_retries += 1
            published = False
            continue
        # Nodes the receiver's quarantine policy refused were NOT published
        # — they must stay out of the merge base below, or the next pull
        # would read their absence on the remote as a remote deletion and
        # silently delete the local copy.
        server_rejected = sorted((ack or {}).get("quarantined_rejected", []))
        break
    if published:
        transport.finalize([n["artifact_ref"] for n in merged["nodes"]
                            if n.get("artifact_ref")])
        # Advance the merge base: drop nodes no longer on the remote, then
        # record as newly common ONLY the pushed nodes the remote accepted
        # verbatim — a node the remote-side merge reshaped is not yet agreed.
        # Quarantined names never enter the base (they were not synced), so
        # every later push keeps treating the remote's copy as remote-only
        # content to preserve rather than a deletion to propagate.
        merged_by_name = {n["name"]: n for n in merged["nodes"]}
        skip = set(quarantined_skipped) | set(server_rejected)
        old = state.load() or {"nodes": []}
        base_nodes = {n["name"]: n for n in old["nodes"]
                      if n["name"] in merged_by_name
                      and n["name"] not in skip}
        for node in selected:
            if (node["name"] not in skip
                    and merged_by_name.get(node["name"]) == node):
                base_nodes[node["name"]] = node
        state.save({"nodes": list(base_nodes.values())})

    return SyncReport(direction="push",
                      selected_nodes=[n["name"] for n in selected],
                      objects_total=plan.total, objects_transferred=moved,
                      bytes_transferred=moved_bytes, chunks_resumed=resumed,
                      publish_retries=publish_retries, flattened=flattened,
                      quarantined_skipped=quarantined_skipped,
                      quarantine_rejected_by_remote=server_rejected,
                      merge=report, published=published,
                      **_retry_delta(transport, retry_before))


def pull(graph: LineageGraph, transport: Transport,
         filter: Optional[str] = None, state: Optional[RemoteState] = None,
         chunk_size: int = CHUNK_OBJECTS) -> SyncReport:
    """Fetch the (filtered) remote subgraph and reconcile it into ``graph``.

    A shallow pull (``filter``) brings only the matching nodes into the
    lineage document, but the object transfer still completes their delta
    chains (chain-parent manifests ride along as storage-only objects), so
    every pulled parameter reconstructs. Divergent nodes auto-merge at the
    artifact level when the paper-§5 decision tree allows; ``conflict`` keeps the
    local version and is reported."""
    with span("sync.pull", cat="remote"):
        return _pull(graph, transport, filter, state, chunk_size)


def _pull(graph: LineageGraph, transport: Transport,
          filter: Optional[str], state: Optional[RemoteState],
          chunk_size: int) -> SyncReport:
    store = graph.store
    if store is None:
        raise ValueError("pull requires a store-backed lineage graph")
    state = state or RemoteState(None, None)
    retry_before = _retry_snapshot(transport)
    repo = graph.path or store.cas.root or "."

    remote_payload = transport.fetch_lineage()
    if remote_payload is None:
        remote_payload = {"nodes": []}
    selected = _select_nodes(remote_payload, filter)
    refs = [n["artifact_ref"] for n in selected if n.get("artifact_ref")]
    fetch = _ImportingFetch(store, transport)  # negotiation reads are kept
    with span("sync.negotiate", cat="remote"):
        closure = walk_manifests(fetch, refs)
        local_have = {k for k in closure_keys(closure)
                      if store.cas.has(k)}
        plan = plan_transfer(closure, local_have)

    def move_chunk(keys: List[str]) -> int:
        objs = fetch_objects(transport, keys)
        store.import_objects(objs)
        return sum(len(v) for v in objs.values())

    tid = transfer_id(plan.order, "pull")
    with span("sync.transfer", cat="remote", direction="pull",
              objects=len(plan.wants)):
        moved, moved_bytes, resumed = run_journalled_transfer(
            LocalJournalStore(repo), tid, plan.order, plan.wants, "pull",
            move_chunk, chunk_size)
    moved += fetch.imported
    moved_bytes += fetch.imported_bytes

    merged, report = merge_lineage(_scoped(state.load(), filter),
                                   graph.to_payload(), {"nodes": selected},
                                   store=store)
    graph.replace_nodes(merged)
    store.rebuild_refcounts([n.artifact_ref for n in graph.nodes.values()
                             if n.artifact_ref])
    # Advance the merge base: keep out-of-scope base nodes, replace the
    # in-scope portion with what the remote now says — EXCEPT nodes that
    # conflicted. Those were NOT integrated (local kept), so recording the
    # remote's version as "agreed" would make the next push classify the
    # still-divergent node as fast-forward and silently clobber the remote.
    old = state.load() or {"nodes": []}
    old_by_name = {n["name"]: n for n in old["nodes"]}
    conflicts = set(report.conflicts)
    keep = [n for n in old["nodes"]
            if filter is not None and not fnmatch.fnmatch(n["name"], filter)]
    advanced = []
    for node in selected:
        if node["name"] in conflicts:
            if node["name"] in old_by_name:  # last agreed version, if any
                advanced.append(old_by_name[node["name"]])
        else:
            advanced.append(node)
    state.save({"nodes": keep + advanced})

    return SyncReport(direction="pull",
                      selected_nodes=[n["name"] for n in selected],
                      objects_total=plan.total, objects_transferred=moved,
                      bytes_transferred=moved_bytes, chunks_resumed=resumed,
                      merge=report,
                      **_retry_delta(transport, retry_before))


def clone(url: str, dest: str, filter: Optional[str] = None) -> SyncReport:
    """Materialize a remote repo into the fresh directory ``dest``.

    ``url`` is a peer directory or an ``http(s)://`` hub. Sets up
    ``origin`` tracking state so later ``pull``/``push`` three-way merge
    against what was cloned."""
    from repro.store import ArtifactStore  # local import: store pulls in jax
    os.makedirs(dest, exist_ok=True)
    if os.path.exists(os.path.join(dest, "lineage.json")):
        raise ValueError(f"destination {dest!r} is already a lineage repo")
    remote_add(dest, "origin", url)
    graph = LineageGraph(path=dest, store=ArtifactStore(root=dest))
    transport, _ = resolve_transport(dest, "origin")
    return pull(graph, transport, filter=filter,
                state=RemoteState(dest, "origin"))


def fetch_param_shard(store, transport: Transport, ref: str, key: str,
                      shard: int, n_shards: int) -> bytes:
    """Pull and materialize one host's axis-0 shard of a stored parameter.

    The shard-granular half of DESIGN.md §12: because commit-time chunk
    grids never straddle the mesh shard boundaries (``shard_cuts`` segments
    are hard cuts), host ``shard`` of ``n_shards`` can fetch exactly the
    chunk objects covering its own rows — for a tensor-parallel consumer
    that is ``1/n_shards`` of the wire bytes per host instead of every host
    pulling the full tensor. Parameters the placement rules replicate (and
    sub-threshold, non-chunked ones) fall back to fetching the whole value.
    Returns the shard's raw little-endian truth bytes."""
    if not 0 <= shard < n_shards:
        raise ValueError(f"shard {shard} out of range for {n_shards}")
    from repro.dist.sharding import shard_cuts

    # the manifest chain must be local before chunk refs can be resolved;
    # negotiation-style importing fetch keeps what it pulls
    walk_manifests(_ImportingFetch(store, transport), [ref])
    e = store.get_manifest(ref)["params"][key]
    shape = tuple(int(d) for d in e["shape"])
    itemsize = np.dtype(e["dtype"]).itemsize
    nbytes = int(e.get("nbytes")
                 or np.prod(shape, dtype=np.int64) * itemsize)
    cuts = shard_cuts(key, shape, itemsize, n_shards)
    bounds = [0] + (cuts or []) + [nbytes]
    if cuts is None:
        start, end = 0, nbytes      # replicated placement: full tensor
    else:
        start, end = bounds[shard], bounds[shard + 1]

    if e["kind"] == "chunked":
        needed = store.chunk_range_objects(ref, key, start, end)
    else:
        # sub-threshold param: walk its per-key chain (full tensor or
        # delta blobs down to the base) — still only this key's objects
        needed, cur = [], ref
        while True:
            ce = store.get_manifest(cur)["params"][key]
            if ce["kind"] == "chunked":
                needed += store.chunk_range_objects(
                    cur, key, 0, int(ce["nbytes"]))
                break
            needed.append(ce["tensor"] if ce["kind"] == "full"
                          else ce["blob"])
            if ce["kind"] != "delta":
                break
            cur = ce["parent_ref"]
    missing = [k for k in dict.fromkeys(needed) if not store.cas.has(k)]
    if missing:
        store.import_objects(fetch_objects(transport, missing))
    return store.materialize_param_range(ref, key, start, end)
