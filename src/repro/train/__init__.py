from repro.train.loop import Trainer
from repro.train.step import (cross_entropy, init_state, make_loss_fn,
                              make_train_step)

__all__ = ["Trainer", "cross_entropy", "init_state", "make_loss_fn",
           "make_train_step"]
