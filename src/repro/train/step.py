"""train_step / loss: next-token LM objective with microbatched grad accumulation.

TrainState is a plain dict (checkpoint-friendly via ``repro.store``):
  {"params": <model pytree>, "opt": OptState, "step": int32,
   ["err": error-feedback pytree when gradient compression is on]}
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.dist import compression
from repro.models.config import ModelConfig
from repro.models.model import forward, init_params
from repro.optim import adamw


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token NLL. logits: (B, S, V) (vocab may be model-sharded)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    label_logit = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - label_logit)


def make_loss_fn(cfg: ModelConfig):
    def loss_fn(params, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        logits = forward(cfg, params, batch)
        tokens = batch["tokens"]
        return cross_entropy(logits[:, :-1], tokens[:, 1:])
    return loss_fn


def init_state(cfg: ModelConfig, seed: int = 0,
               compress_grads: bool = False) -> Dict[str, Any]:
    params = init_params(cfg, seed)
    state = {"params": params, "opt": adamw.init(params),
             "step": jnp.zeros((), jnp.int32)}
    if compress_grads:
        state["err"] = compression.init_error_state(params)
    return state


def _split_microbatches(batch: Dict[str, jnp.ndarray], n: int):
    def reshape(x):
        return x.reshape((n, x.shape[0] // n) + x.shape[1:])
    return jax.tree_util.tree_map(reshape, batch)


def make_train_step(cfg: ModelConfig, opt_cfg: Optional[adamw.AdamWConfig] = None,
                    n_microbatches: int = 1, compress_grads: bool = False):
    """Build the jittable train_step(state, batch) -> (state, metrics).

    Microbatching scans over ``n_microbatches`` slices of the global batch and
    accumulates fp32 gradients — peak activation memory scales with the
    microbatch, not the global batch. Gradient compression (int8 + error
    feedback) models the cross-pod DCN reduction (dist/compression.py).
    """
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    loss_fn = make_loss_fn(cfg)

    def train_step(state: Dict[str, Any], batch: Dict[str, jnp.ndarray]):
        params = state["params"]

        if n_microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            mbs = _split_microbatches(batch, n_microbatches)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, x: a + x.astype(jnp.float32), acc, g)
                return acc, l

            grads, losses = jax.lax.scan(body, zero, mbs)
            grads = jax.tree_util.tree_map(lambda g: g / n_microbatches, grads)
            loss = jnp.mean(losses)

        new_state = dict(state)
        if compress_grads:
            grads, new_err = compression.compress_gradients(grads, state["err"])
            new_state["err"] = new_err

        new_params, new_opt, metrics = adamw.update(opt_cfg, grads,
                                                    state["opt"], params)
        new_state.update(params=new_params, opt=new_opt,
                         step=state["step"] + 1)
        metrics["loss"] = loss
        return new_state, metrics

    return train_step
