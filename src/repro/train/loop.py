"""Fault-tolerant training loop: MGit-versioned checkpoints, restart, stragglers.

The Trainer wires together the substrates: synthetic pipeline, jitted
train_step (sharded when a mesh is given), CheckpointManager (every
checkpoint is an MGit version node; restart resumes from the latest committed
one, including onto a different mesh), and the straggler monitor.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.data import SyntheticPipeline
from repro.ft import ElasticRestart, StepTimer, StragglerPolicy
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.store.checkpoint import CheckpointManager
from repro.train.step import init_state, make_train_step


class Trainer:
    def __init__(self, cfg: ModelConfig, *, batch: int = 8, seq: int = 128,
                 opt_cfg: Optional[adamw.AdamWConfig] = None,
                 n_microbatches: int = 1, compress_grads: bool = False,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 50, mesh: Optional[Any] = None,
                 seed: int = 0,
                 on_metrics: Optional[Callable[[int, Dict], None]] = None,
                 commit_every: Optional[int] = None,
                 lossy_tier: bool = False, keyframe_every: int = 8):
        self.cfg = cfg
        self.mesh = mesh
        # ``commit_every`` is the continuous-checkpointing cadence knob
        # (DESIGN.md §15) — it overrides the legacy checkpoint_every name
        self.checkpoint_every = (commit_every if commit_every is not None
                                 else checkpoint_every)
        self.on_metrics = on_metrics
        self.pipeline = SyntheticPipeline(cfg, batch=batch, seq=seq, mesh=mesh,
                                          seed=seed)
        self.train_step = jax.jit(make_train_step(
            cfg, opt_cfg, n_microbatches=n_microbatches,
            compress_grads=compress_grads), donate_argnums=(0,))
        self.state = init_state(cfg, seed, compress_grads=compress_grads)
        self.timer = StepTimer()
        self.ckpt: Optional[CheckpointManager] = None
        self.start_step = 0
        if checkpoint_dir is not None:
            self.ckpt = CheckpointManager(
                checkpoint_dir, model_name=cfg.name,
                tier="lossy" if lossy_tier else "exact",
                keyframe_every=keyframe_every)
            latest = self.ckpt.latest_step()
            if latest is not None:  # crash restart: resume from last commit
                # the lossy tier may resolve to the nearest exact ancestor,
                # so resume from the step restore actually returned
                self.state, restored = self.ckpt.restore(step=latest,
                                                         template=self.state)
                self.start_step = restored
                self.pipeline.step = restored
        # straggler escalation bottoms out in evict + elastic restart from
        # the last committed version (ft/straggler.py) when versioning is on
        self.elastic = ElasticRestart(self) if self.ckpt is not None else None
        self.policy = StragglerPolicy(evict_fn=self.elastic)

    def run(self, n_steps: int) -> Dict[str, list]:
        history: Dict[str, list] = {"loss": [], "step_time": []}
        for step in range(self.start_step, self.start_step + n_steps):
            batch = self.pipeline.host_batch(step)
            t0 = time.perf_counter()
            self.state, metrics = self.train_step(self.state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            history["loss"].append(loss)
            history["step_time"].append(dt)
            event = self.timer.record(step, dt)
            if event is not None:
                self.policy.on_event(event)
            if self.ckpt is not None and (step + 1) % self.checkpoint_every == 0:
                self.ckpt.save(step + 1, self.state)  # async, MGit-versioned
            if self.on_metrics is not None:
                self.on_metrics(step, {"loss": loss, "step_time": dt, **{
                    k: float(v) for k, v in metrics.items() if k != "loss"}})
        if self.ckpt is not None:
            self.ckpt.wait()
        return history
