"""StarCoder2-15B [arXiv:2402.19173; hf] — dense GQA, RoPE, GELU MLP."""

from repro.models.config import ModelConfig, register_arch


@register_arch("starcoder2-15b")
def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b", family="dense",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, head_dim=128,
        d_ff=24576, vocab_size=49152, mlp_type="gelu", rope_theta=1e5,
        remat="full", subquadratic=False,
    )
