"""PaliGemma-3B [arXiv:2407.07726; hf] — SigLIP + gemma decoder (MQA kv=1).

The SigLIP vision tower is a STUB per the assignment: ``input_specs`` feeds
256 precomputed patch embeddings (B, 256, d_model); the gemma-style decoder
backbone (18L, 8H MQA, head_dim 256) is real, with a prefix-LM mask over the
visual prefix.
"""

from repro.models.config import ModelConfig, register_arch


@register_arch("paligemma-3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b", family="vlm",
        n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
        d_ff=16384, vocab_size=257216, mlp_type="swiglu",
        frontend="vision_stub", n_prefix_tokens=256, tie_embeddings=True,
        remat="full", subquadratic=False,
    )
