"""Yi-6B [arXiv:2403.04652; hf] — llama-architecture GQA, SwiGLU."""

from repro.models.config import ModelConfig, register_arch


@register_arch("yi-6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
        d_ff=11008, vocab_size=64000, mlp_type="swiglu", rope_theta=5e6,
        remat="full", subquadratic=False,
    )
