"""Mixtral-8x7B [arXiv:2401.04088; hf] — 8 experts top-2, sliding-window attn.

SWA (W=4096) bounds the KV cache -> runs the long_500k cell with a ring
buffer cache.
"""

from repro.models.config import ModelConfig, register_arch


@register_arch("mixtral-8x7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=32000, mlp_type="swiglu",
        n_experts=8, experts_per_token=2, window=4096,
        rope_theta=1e6, remat="full", subquadratic=True,
    )
