"""The paper's own evaluation subject: a BERT-base-like encoder LM (~110M).

MGit's G1/G2/G5 graphs are built from BERT/RoBERTa-family models; this config
is the trainable stand-in used by the end-to-end examples (finetune lineages,
update cascades) and the compression benchmarks at realistic scale.
"""

from repro.models.config import ModelConfig, register_arch


@register_arch("paper-bert")
def config() -> ModelConfig:
    return ModelConfig(
        name="paper-bert", family="dense",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
        d_ff=3072, vocab_size=30522, mlp_type="gelu",
        remat="dots", subquadratic=False,
    )


@register_arch("paper-bert-small")
def config_small() -> ModelConfig:
    """~14M variant for fast end-to-end examples on CPU."""
    return ModelConfig(
        name="paper-bert-small", family="dense",
        n_layers=4, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=1024, vocab_size=8192, mlp_type="gelu", dtype="float32",
        remat="none", subquadratic=False,
    )
