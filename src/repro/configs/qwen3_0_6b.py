"""Qwen3-0.6B [hf:Qwen/Qwen3-8B family; hf] — GQA with per-head qk RMSNorm."""

from repro.models.config import ModelConfig, register_arch


@register_arch("qwen3-0.6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b", family="dense",
        n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=128,
        d_ff=3072, vocab_size=151936, mlp_type="swiglu", qk_norm=True,
        rope_theta=1e6, tie_embeddings=True,
        remat="full", subquadratic=False,
    )
