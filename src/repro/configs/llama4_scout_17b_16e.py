"""Llama-4-Scout-17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E] — MoE top-1.

16 routed experts (top-1) + 1 shared expert per layer. The early-fusion
vision frontend is out of the assigned backbone scope (entry tagged [moe]);
text path only.
"""

from repro.models.config import ModelConfig, register_arch


@register_arch("llama4-scout-17b-16e")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-16e", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab_size=202048, mlp_type="swiglu",
        n_experts=16, experts_per_token=1, n_shared_experts=1,
        rope_theta=5e5, remat="full", subquadratic=False,
    )
