"""Assigned architecture configs (public literature; see each module's source note).

Importing this package populates the registry used by ``get_config``/``--arch``.
"""

from repro.configs import (deepseek_coder_33b, jamba_1_5_large_398b,
                           llama4_scout_17b_16e, mamba2_780m, mixtral_8x7b,
                           paligemma_3b, paper_bert_pool, qwen3_0_6b,
                           seamless_m4t_large_v2, starcoder2_15b, yi_6b)

__all__ = [
    "starcoder2_15b", "yi_6b", "qwen3_0_6b", "deepseek_coder_33b",
    "seamless_m4t_large_v2", "mamba2_780m", "llama4_scout_17b_16e",
    "mixtral_8x7b", "jamba_1_5_large_398b", "paligemma_3b", "paper_bert_pool",
]
