"""SeamlessM4T-large-v2 [arXiv:2308.11596; hf] — encoder-decoder, multimodal.

The speech frontend (w2v-BERT conformer feature extractor) is a STUB per the
assignment: ``input_specs`` feeds precomputed frame embeddings (B, S, d_model).
The transformer backbone (24L enc + 24L dec, MHA kv=16, GELU) is real.
"""

from repro.models.config import ModelConfig, register_arch


@register_arch("seamless-m4t-large-v2")
def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="audio",
        n_layers=24, n_encoder_layers=24, d_model=1024, n_heads=16,
        n_kv_heads=16, head_dim=64, d_ff=8192, vocab_size=256206,
        mlp_type="gelu", frontend="audio_stub",
        remat="full", subquadratic=False,
    )
