"""DeepSeek-Coder-33B [arXiv:2401.14196; hf] — llama-architecture GQA."""

from repro.models.config import ModelConfig, register_arch


@register_arch("deepseek-coder-33b")
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b", family="dense",
        n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
        d_ff=19200, vocab_size=32256, mlp_type="swiglu", rope_theta=1e5,
        remat="full", subquadratic=False,
    )
