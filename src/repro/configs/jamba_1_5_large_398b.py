"""Jamba-1.5-Large (398B) [arXiv:2403.19887; hf] — hybrid Mamba+attention MoE.

1:7 attention:mamba interleave (one attention layer per 8, at offset 4), MoE
(16 experts, top-2) on every other layer. Adaptation note (DESIGN.md): mamba
sublayers use our Mamba2/SSD block (state=128) rather than Mamba-1 (state=16)
— the framework's SSM primitive — preserving the hybrid structure and
compute/memory character. SSM layers keep O(1) decode state -> long_500k runs
(attention layers hold the full 500k KV, sharded).
"""

from repro.models.config import ModelConfig, register_arch


@register_arch("jamba-1.5-large-398b")
def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=24576, vocab_size=65536, mlp_type="swiglu",
        n_experts=16, experts_per_token=2,
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
        attn_period=8, attn_offset=4, moe_period=2,
        remat="full", subquadratic=True,
    )
