"""Mamba2-780M [arXiv:2405.21060] — attention-free SSD (state-space duality).

O(1)-state recurrent decode -> runs the long_500k cell.
"""

from repro.models.config import ModelConfig, register_arch


@register_arch("mamba2-780m")
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="ssm",
        n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0, d_ff=0,
        vocab_size=50280, ssm_state=128, ssm_expand=2, ssm_head_dim=64,
        ssm_chunk=256, tie_embeddings=True,
        remat="full", subquadratic=True,
    )
