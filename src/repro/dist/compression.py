"""Gradient compression for the cross-pod reduction (int8 + error feedback).

Gradients crossing the DCN between pods are quantized to int8 with one fp32
scale per tensor; the quantization residual is carried forward in an error
state so the long-run average of the dequantized stream is unbiased (EF-SGD).
Everything is shape-static and jittable — the train step folds it in.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_Q_LEVELS = 127.0


def init_error_state(params: Any) -> Any:
    """Zeroed fp32 error-feedback pytree matching ``params``."""
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(jnp.shape(p), jnp.float32), params)


def _compress_leaf(g: jnp.ndarray, err: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    c = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(c)), 1e-30) / _Q_LEVELS
    q = jnp.clip(jnp.round(c / scale), -_Q_LEVELS, _Q_LEVELS).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq.astype(g.dtype), c - deq


def compress_gradients(grads: Any, err: Any) -> Tuple[Any, Any]:
    """Quantize ``grads`` to int8 wire format and immediately dequantize.

    Returns ``(dequantized_grads, new_error_state)``. The dequantized values
    are what the optimizer consumes (they model what arrives after the
    compressed all-reduce); the residual goes back into the error state.
    """
    g_leaves, treedef = jax.tree_util.tree_flatten(grads)
    e_leaves = treedef.flatten_up_to(err)
    outs = [_compress_leaf(g, e) for g, e in zip(g_leaves, e_leaves)]
    deq = jax.tree_util.tree_unflatten(treedef, [d for d, _ in outs])
    new_err = jax.tree_util.tree_unflatten(treedef, [e for _, e in outs])
    return deq, new_err


def compressed_bytes(grads: Any) -> int:
    """Wire bytes for one compressed reduction: 1 byte/element + 4-byte scale
    per tensor."""
    leaves = jax.tree_util.tree_leaves(grads)
    return sum(int(np.prod(np.shape(l))) for l in leaves) + 4 * len(leaves)


def ef_eps(amax: float) -> float:
    """Checkpoint-tier bridge to this module's int8 estimator (§15).

    The lossy step-delta commit sizes its per-leaf quantization grid to
    match what one error-feedback round would use for the same update:
    ``quant_scale(eps) == amax / _Q_LEVELS`` (``quant_scale`` is
    ``2*log1p(eps)``, so eps inverts through expm1). With the grid matched,
    every quantized step-delta narrows to int8 and its per-hop error is
    bounded by half the EF grid — the checkpoint never loses more than the
    wire compression already tolerates."""
    return max(float(np.expm1((amax / _Q_LEVELS) / 2.0)), 1e-12)
