"""Single-device distribution shim: sharding rules + gradient compression.

The production system runs SPMD over a (pod, data, model) mesh; this package
holds the pieces the rest of the codebase programs against. On a single-device
host every sharding call degrades to the identity, so models, training, and
the launch dry-runs share one code path.
"""

from repro.dist import compression
from repro.dist.sharding import (batch_spec, get_mesh, param_spec, shard,
                                 use_mesh)

__all__ = ["compression", "shard", "param_spec", "batch_spec", "use_mesh",
           "get_mesh"]
