"""Sharding rules + mesh plumbing (single-device shim with full-mesh semantics).

``shard(x, *entries)`` applies a per-dimension sharding constraint when a mesh
is active (installed via ``use_mesh``) and is the identity otherwise, so model
code is written once for the production (pod, data, model) mesh and still runs
on a laptop CPU. Entries are mesh-axis names, tuples of names (an axis group
like ``("pod", "data")``), or None (replicated); axes absent from the active
mesh are dropped at resolution time, which is how the 2-axis host mesh and the
3-axis multi-pod mesh share one rule set.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Iterable, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Entry = Union[None, str, Tuple[str, ...]]

_state = threading.local()


def get_mesh() -> Optional[Mesh]:
    """The mesh installed by the innermost ``use_mesh``, or None."""
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    """Install ``mesh`` as the active mesh for ``shard``/``get_mesh``."""
    prev = get_mesh()
    _state.mesh = mesh
    try:
        yield mesh
    finally:
        _state.mesh = prev


def _resolve_entry(e: Entry, axes: Iterable[str]) -> Entry:
    """Drop mesh axes not present in ``axes``; collapse singleton tuples."""
    if e is None:
        return None
    axes = set(axes)
    names = (e,) if isinstance(e, str) else tuple(e)
    present = tuple(n for n in names if n in axes)
    if not present:
        return None
    if len(present) == 1:
        return present[0]
    return present


def shard(x: Any, *entries: Entry) -> Any:
    """Constrain ``x``'s sharding per dimension under the active mesh.

    No-op when no mesh is active (single-device paths, unit tests)."""
    mesh = get_mesh()
    if mesh is None:
        return x
    axes = set(mesh.axis_names)
    spec = P(*[_resolve_entry(e, axes) for e in entries])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_spec(mesh: Mesh, *trailing: Entry) -> NamedSharding:
    """Sharding for a host batch: leading (batch) dim over the data axes."""
    axes = set(mesh.axis_names)
    lead = _resolve_entry(("pod", "data"), axes)
    return NamedSharding(
        mesh, P(lead, *[_resolve_entry(e, axes) for e in trailing]))


# ---------------------------------------------------------------------------
# parameter placement rules
# ---------------------------------------------------------------------------

_NORM_LEAVES = ("norm", "scale", "bias", "gamma", "beta")


def param_spec(path: str, ndim: int) -> P:
    """PartitionSpec for a parameter by its flat path + rank.

    Rules (megatron-style tensor parallelism + data-parallel ZeRO over the
    reduce dimension):
      * norm / scale / bias leaves: replicated;
      * embeddings: vocab over ``model``, feature over ``data``;
      * MoE expert weights (rank >= 3 under a moe/expert layer): experts over
        ``model``, the contracting dim over ``data``;
      * generic matmul weights: contracting dim over ``data``, output dim
        over ``model``; leading (stacked-layer) dims replicated.
    """
    leaf = path.rsplit("/", 1)[-1]
    if leaf.startswith("ln") or any(tag in leaf for tag in _NORM_LEAVES):
        return P(*([None] * ndim))
    if ndim <= 1:
        return P(*([None] * ndim))
    if "embed" in path:
        return P("model", "data", *([None] * (ndim - 2)))
    if ("moe" in path or "expert" in path) and ndim >= 3:
        return P(*([None] * (ndim - 3)), "model", "data", None)
    return P(*([None] * (ndim - 2)), "data", "model")


def shard_cuts(path: str, shape, itemsize: int,
               n_shards: int) -> Optional[list]:
    """Byte offsets where ``n_shards`` axis-0 shards of this param begin/end.

    The chunk layer (``store/chunks.py``, DESIGN.md §12) uses these as hard
    segment boundaries so no chunk straddles two shards — each host of a
    distributed consumer can then pull exactly the chunk set covering its
    own shard. Only axis-0 sharding produces *contiguous* byte ranges in a
    C-order tensor, so cuts exist only when :func:`param_spec` shards
    dimension 0 (2-D matmul weights shard dim 0 over ``data``, embeddings
    over ``model``); replicated or inner-dim-only placements return None.
    """
    shape = tuple(int(d) for d in shape)
    if n_shards <= 1 or len(shape) < 2:
        return None
    spec = param_spec(path, len(shape))
    if not tuple(spec) or tuple(spec)[0] is None:
        return None
    rows = shape[0]
    if rows < n_shards:
        return None
    row_bytes = itemsize
    for d in shape[1:]:
        row_bytes *= d
    # same split arithmetic as jax's even-ceil sharding over axis 0
    cuts = []
    for s in range(1, n_shards):
        cuts.append((s * rows) // n_shards * row_bytes)
    return cuts
