"""Lineage-native model pool: one resident base, N delta-derived views.

The storage argument of the paper — dozens of finetunes share structure and
parameters with one base — has a serving analogue (DESIGN.md §13): keep the
chain base's parameters resident ONCE and materialize each derivative as a
delta application over them, so serving memory dedups the same way the CAS
does. The pool:

* loads the chain base of a manifest family exactly once (batched
  ``materialize_artifact`` checkout, PR 4) and pins it;
* derives each served node's ``ResidentView`` by applying its folded
  per-segment deltas directly over the resident base arrays — fused
  ``ops.chain_apply`` on device backends, int32 segment sum + one host
  dequant per segment on CPU (bit-identical, DESIGN.md §10.2);
* aliases every parameter whose content hash matches a base parameter
  (the common case for sparse finetunes: unchanged tensors cost zero
  bytes per derivative);
* asserts bit-identity of every non-aliased parameter against the
  manifest's stored truth hash — a view that diverges from what
  ``load_artifact`` would return raises instead of serving;
* keeps an LRU over the derivative views' private (non-aliased) bytes, so
  N models stay resident in a fraction of N full copies.

Chunked (``kind: chunked``) parameters and stores with folding disabled
route through ``store.materialize_param`` — the chunk engine and the
hopwise executor are the reconstruction truth there — and get the same
bit-identity check.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.common.hashing import tensor_hash
from repro.core.artifact import ModelArtifact
from repro.core.graphir import LayerGraph
from repro.obs import REGISTRY, span
from repro.store.delta import decode_q, host_dequant


class BitIdentityError(AssertionError):
    """A pool-built parameter diverged from the manifest's stored truth."""


class ResidentView:
    """One served derivative: params resident over (mostly) base aliases.

    Lease accounting makes hot swaps drain-safe: a request holds a lease
    for its whole read, an endpoint swap only replaces the *pointer*, and
    the old view object stays fully usable until its last lease releases
    (``active_leases`` -> 0). Nothing is freed under an in-flight request.
    """

    def __init__(self, ref: str, artifact: ModelArtifact,
                 aliased: List[str], private_bytes: int,
                 build_s: float) -> None:
        self.ref = ref
        self.artifact = artifact
        self.aliased = aliased            # param keys borrowed from the base
        self.private_bytes = private_bytes
        self.build_s = build_s
        self.active_leases = 0
        self._lock = threading.Lock()

    @property
    def params(self) -> Dict[str, np.ndarray]:
        return self.artifact.params

    def acquire(self) -> None:
        with self._lock:
            self.active_leases += 1

    def release(self) -> None:
        with self._lock:
            self.active_leases -= 1

    def probe(self, x: Optional[np.ndarray] = None) -> np.ndarray:
        """Deterministic forward probe through the layer graph.

        Chains ``tanh(x @ w)`` through every 2-D parameter the running
        width matches, in topological order — the generic "response" for
        artifacts with no transformer config attached. Branch-pinned
        endpoints over different derivatives return different probes, and
        identical params always return identical probes."""
        ws = []
        for name in self.artifact.graph.topo_order():
            for pname, value in sorted(self.params.items()):
                if pname.startswith(name + "/") and np.ndim(value) == 2:
                    ws.append(np.asarray(value, np.float32))
        if not ws:
            raise ValueError(f"view {self.ref!r} has no 2-D params to probe")
        if x is None:
            x = np.ones((1, ws[0].shape[0]), np.float32)
        x = np.asarray(x, np.float32)
        for w in ws:
            if x.shape[-1] != w.shape[0]:
                continue
            x = np.tanh(x @ w)
        return x

    def stats(self) -> Dict[str, Any]:
        return {"ref": self.ref, "params": len(self.params),
                "aliased": len(self.aliased),
                "private_bytes": self.private_bytes,
                "active_leases": self.active_leases,
                "build_s": round(self.build_s, 6)}


class ModelPool:
    """LRU pool of :class:`ResidentView`\\ s over one pinned chain base.

    ``backend`` follows the kernels convention: ``None``/``"ref"`` apply
    segments on the host (int32 sum + one dequant — bit-identical to the
    fused kernel), anything else dispatches ``ops.chain_apply``.
    ``verify=False`` skips the per-param truth-hash assertion (benchmarks
    measuring raw build latency); serving keeps it on.
    """

    def __init__(self, store, max_resident: int = 8,
                 budget_bytes: Optional[int] = None,
                 backend: Optional[str] = None, verify: bool = True) -> None:
        self.store = store
        self.max_resident = max_resident
        self.budget_bytes = budget_bytes
        self.backend = backend
        self.verify = verify
        self._lock = threading.RLock()
        self._views: "OrderedDict[str, ResidentView]" = OrderedDict()
        self._base_ref: Optional[str] = None
        self._base_by_hash: Dict[str, np.ndarray] = {}
        self.base_bytes = 0
        # registry-backed compat view (mgit_pool_* in /api/metrics)
        self.stats_counters = REGISTRY.group(
            "mgit_pool",
            keys=("views_built", "hits", "misses", "evictions",
                  "params_aliased", "params_applied", "chain_hops",
                  "segments_applied", "fused_applies", "params_verified",
                  "bytes_aliased"),
            help="serving pool residency counters")

    # -- base residency ------------------------------------------------------
    def base_ref_of(self, ref: str) -> str:
        """The depth-0 manifest under ``ref``'s delta-parent chain."""
        seen = set()
        cur = ref
        while True:
            if cur in seen:
                raise RuntimeError(f"delta_parents cycle at {cur!r}")
            seen.add(cur)
            parents = self.store.get_manifest(cur).get("delta_parents", [])
            if not parents:
                return cur
            cur = sorted(parents)[0]

    def ensure_base(self, ref: str) -> str:
        """Pin ``ref``'s chain base: one batched checkout, kept for the
        pool's lifetime. Returns the base manifest ref."""
        base_ref = self.base_ref_of(ref)
        with self._lock:
            if self._base_ref == base_ref:
                return base_ref
            if self._base_ref is not None:
                raise ValueError(
                    f"pool already resident on base {self._base_ref!r}; "
                    f"{ref!r} descends from {base_ref!r} — use one pool "
                    "per model family")
        artifact = self.store.materialize_artifact(base_ref)
        manifest = self.store.get_manifest(base_ref)
        by_hash: Dict[str, np.ndarray] = {}
        total = 0
        for key, entry in manifest["params"].items():
            value = np.asarray(artifact.params[key])
            by_hash[entry["hash"]] = value
            total += int(value.nbytes)
        with self._lock:
            self._base_ref = base_ref
            self._base_by_hash = by_hash
            self.base_bytes = total
        return base_ref

    # -- view residency ------------------------------------------------------
    def get(self, ref: str) -> ResidentView:
        """Resident view for ``ref`` (LRU: builds on miss, evicts beyond
        the resident budget; evicted views stay alive while leased)."""
        with self._lock:
            view = self._views.get(ref)
            if view is not None:
                self._views.move_to_end(ref)
                self.stats_counters["hits"] += 1
                return view
            self.stats_counters["misses"] += 1
        view = self._build_view(ref)
        with self._lock:
            self._views[ref] = view
            self._views.move_to_end(ref)
            self._evict_over_budget()
        return view

    def _evict_over_budget(self) -> None:
        def over() -> bool:
            if len(self._views) > self.max_resident:
                return True
            if self.budget_bytes is None:
                return False
            return sum(v.private_bytes
                       for v in self._views.values()) > self.budget_bytes
        while len(self._views) > 1 and over():
            self._views.popitem(last=False)
            self.stats_counters["evictions"] += 1

    def _build_view(self, ref: str) -> ResidentView:
        t0 = time.perf_counter()
        with span("pool.build_view", cat="serve", ref=ref):
            return self._build_view_inner(ref, t0)

    def _build_view_inner(self, ref: str, t0: float) -> ResidentView:
        self.ensure_base(ref)
        manifest = self.store.get_manifest(ref)
        params: Dict[str, np.ndarray] = {}
        aliased: List[str] = []
        private = 0
        for key, entry in manifest["params"].items():
            truth = entry["hash"]
            base_twin = self._base_by_hash.get(truth)
            if base_twin is not None:
                # content-addressed dedup: bit-identity holds by the hash
                # equality itself — no bytes, no verification pass needed
                params[key] = base_twin
                aliased.append(key)
                self._count(params_aliased=1,
                            bytes_aliased=int(base_twin.nbytes))
                continue
            if entry["kind"] == "delta" and self.store.fold_enabled:
                value = self._apply_chain(ref, key)
            else:
                # chunked entries, full entries and hopwise-truth stores:
                # the store's own executor IS the reconstruction truth
                value = np.asarray(self.store.materialize_param(ref, key))
            if self.verify:
                got = tensor_hash(value)
                if got != truth:
                    raise BitIdentityError(
                        f"pool-built {ref!r}:{key!r} hash {got} != stored "
                        f"truth {truth}")
                self._count(params_verified=1)
            params[key] = value
            private += int(value.nbytes)
            self._count(params_applied=1)
        artifact = ModelArtifact(
            graph=LayerGraph.from_json(manifest["graph"]),
            params=params,
            model_type=manifest.get("model_type", "generic"),
            metadata=manifest.get("metadata", {}),
        )
        self._count(views_built=1)
        return ResidentView(ref, artifact, aliased, private,
                            time.perf_counter() - t0)

    def _apply_chain(self, ref: str, key: str) -> np.ndarray:
        """Derivative param = base value + folded per-segment deltas.

        Same segmentation rule as the checkout executor (consecutive
        float32 hops sharing one eps fold into one exact int32 sum and ONE
        dequant, DESIGN.md §10.2), but executed over the pool's resident
        base arrays instead of the tensor cache."""
        t_ref, t_key, t_entry, hops = self.store.chain_recipe(ref, key)
        value = self._base_by_hash.get(t_entry["hash"])
        if value is None:
            # chain bottoms out off the resident base (e.g. a chunked
            # terminal): materialize it through the store, cached there
            value = np.asarray(self.store.materialize_param(t_ref, t_key))
        open_qs: List[np.ndarray] = []
        open_eps = 0.0
        for hop in hops:
            q = decode_q(hop, self.store.cas.get_view(hop.blob))
            self._count(chain_hops=1)
            if hop.dtype == "float32":
                if open_qs and hop.eps == open_eps:
                    open_qs.append(q)
                else:
                    if open_qs:
                        value = self._apply_segment(value, open_qs, open_eps)
                    open_qs, open_eps = [q], hop.eps
            else:
                if open_qs:
                    value = self._apply_segment(value, open_qs, open_eps)
                    open_qs = []
                value = host_dequant(value, q, hop.eps,
                                     out_dtype=hop.dtype).reshape(hop.shape)
        if open_qs:
            value = self._apply_segment(value, open_qs, open_eps)
        return np.asarray(value).reshape(hops[-1].shape) if hops \
            else np.asarray(value)

    def _apply_segment(self, value: np.ndarray, qs: List[np.ndarray],
                       eps: float) -> np.ndarray:
        self._count(segments_applied=1)
        if self.backend not in (None, "ref") and len(qs) > 1:
            from repro.kernels import ops
            self._count(fused_applies=1)
            return np.asarray(ops.chain_apply(
                np.asarray(value), qs, eps=eps, backend=self.backend,
                out_dtype="float32"))
        acc = qs[0] if qs[0].dtype == np.int32 else qs[0].astype(np.int32)
        for q in qs[1:]:
            acc = np.add(acc, q.reshape(acc.shape), dtype=np.int32)
        return host_dequant(value, acc, eps, out_dtype="float32")

    # -- bookkeeping ---------------------------------------------------------
    def _count(self, **deltas: int) -> None:
        with self._lock:
            for k, v in deltas.items():
                self.stats_counters[k] += v

    @property
    def resident_refs(self) -> List[str]:
        with self._lock:
            return list(self._views)

    def private_bytes(self) -> int:
        with self._lock:
            return sum(v.private_bytes for v in self._views.values())

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            views = [v.stats() for v in self._views.values()]
        return {
            "base_ref": self._base_ref,
            "base_bytes": self.base_bytes,
            "resident": len(views),
            "private_bytes": sum(v["private_bytes"] for v in views),
            "views": views,
            **self.stats_counters.snapshot(),
        }
