"""Lineage-native serving (DESIGN.md §13).

``repro.serve`` turns the repo into an inference tier: a
:class:`~repro.serve.pool.ModelPool` keeps one chain base resident and
derives N hot-swappable views by delta application (the serving analogue
of the storage dedup), a :class:`~repro.serve.router.Router` maps named
endpoints to *branch heads* with the quarantine flag as a serving gate,
a :class:`~repro.serve.watch.LineageWatcher` hot-swaps endpoints on
lineage publishes (local etag or the hub's ETag'd ``GET /api/lineage``),
and :mod:`repro.serve.routes` exposes it all over HTTP (``cli serve``).
:class:`~repro.serve.engine.ServeEngine` remains the batched transformer
prefill/decode engine for config-bearing model families.
"""

from repro.serve.engine import (ServeEngine, batch_lengths, left_align,
                                make_prefill_step, make_serve_step)
from repro.serve.pool import BitIdentityError, ModelPool, ResidentView
from repro.serve.router import (Endpoint, EndpointUnavailable, Router,
                                parse_endpoint_spec, resolve_branch_head)
from repro.serve.routes import ServeApp, make_server, start_in_thread
from repro.serve.watch import (HubLineageSource, LineageWatcher,
                               LocalLineageSource)

__all__ = [
    "ServeEngine", "batch_lengths", "left_align",
    "make_prefill_step", "make_serve_step",
    "BitIdentityError", "ModelPool", "ResidentView",
    "Endpoint", "EndpointUnavailable", "Router",
    "parse_endpoint_spec", "resolve_branch_head",
    "ServeApp", "make_server", "start_in_thread",
    "HubLineageSource", "LineageWatcher", "LocalLineageSource",
]
