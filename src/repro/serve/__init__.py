from repro.serve.engine import ServeEngine, make_prefill_step, make_serve_step

__all__ = ["ServeEngine", "make_prefill_step", "make_serve_step"]
