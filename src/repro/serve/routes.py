"""HTTP surface of the serving daemon (DESIGN.md §13.4).

Same shape as the hub's route layer (``repro.hub.routes``): a dependency-
free stdlib ``ThreadingHTTPServer`` codec — one OS thread per in-flight
request, which is exactly what the endpoint lease/drain accounting was
designed for (requests hold leases concurrently; swaps move a pointer).

Endpoints (all JSON):

    GET  /api/ping                liveness
    GET  /api/endpoints           endpoint table: node, ref, gate, swaps
    GET  /api/stats               router + pool + watcher counters,
                                  per-route p50/p99
    GET  /api/metrics             Prometheus text exposition (DESIGN §14)
    POST /api/predict/<endpoint>  {"x": [[...]]}? -> {"node","ref","y",...}
    POST /api/refresh             force one watcher poll (CI/tests: no
                                  need to wait out the poll interval)
"""

from __future__ import annotations

import gzip
import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, Optional, Tuple
from urllib.parse import unquote, urlsplit

import numpy as np

from repro.hub.routes import _safe_id
from repro.obs import REGISTRY, Histogram, render_prometheus, span
from repro.remote.http import GZIP_FLOOR
from repro.serve.pool import BitIdentityError, ModelPool
from repro.serve.router import EndpointUnavailable, Router
from repro.serve.watch import LineageWatcher

_FIXED_ROUTES = frozenset({"/api/ping", "/api/endpoints", "/api/stats",
                           "/api/metrics", "/api/refresh"})


def route_family(path: str) -> str:
    """Bounded-cardinality route label (mirrors hub.routes.route_family)."""
    if path.startswith("/api/predict/"):
        return "/api/predict/:endpoint"
    return path if path in _FIXED_ROUTES else "other"


class ServeApp:
    """One router + pool + watcher behind the HTTP codec."""

    def __init__(self, router: Router, pool: ModelPool,
                 watcher: Optional[LineageWatcher] = None) -> None:
        self.router = router
        self.pool = pool
        self.watcher = watcher
        self._lock = threading.Lock()
        # registry-backed compat view (mgit_serve_* in /api/metrics)
        self.counters = REGISTRY.group(
            "mgit_serve",
            keys=("requests", "predictions", "gate_refusals"),
            help="serve daemon request counters")
        self._latency: Dict[Tuple[str, str], Histogram] = {}

    def count(self, **deltas: int) -> None:
        with self._lock:
            for k, v in deltas.items():
                self.counters[k] += v

    def observe_request(self, method: str, route: str,
                        seconds: float) -> None:
        h = self._latency.get((method, route))
        if h is None:
            h = REGISTRY.histogram(
                "mgit_http_request_seconds",
                help="request latency by service/method/route",
                service="serve", instance=self.counters.instance,
                method=method, route=route)
            self._latency[(method, route)] = h
        h.observe(seconds)

    def latency_json(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        for (method, route), h in sorted(self._latency.items()):
            out[f"{method} {route}"] = {
                "count": h.count,
                "p50_ms": round((h.quantile(0.5) or 0.0) * 1e3, 3),
                "p99_ms": round((h.quantile(0.99) or 0.0) * 1e3, 3)}
        return out

    def metrics_text(self) -> str:
        return render_prometheus()

    def stats_json(self) -> Dict[str, Any]:
        out = {"service": "mgit-serve", **self.counters.snapshot(),
               "router": self.router.stats(), "pool": self.pool.stats(),
               "request_latency": self.latency_json()}
        if self.watcher is not None:
            out["watch"] = self.watcher.stats()
        return out


class ServeRequestHandler(BaseHTTPRequestHandler):
    server_version = "mgit-serve/1.0"
    protocol_version = "HTTP/1.1"

    @property
    def app(self) -> ServeApp:
        return self.server.app  # type: ignore[attr-defined]

    def log_message(self, format: str, *args: Any) -> None:
        pass  # request metrics live in app.counters, not stderr

    def _read_json(self) -> Dict:
        length = int(self.headers.get("Content-Length") or 0)
        data = self.rfile.read(length) if length else b""
        if self.headers.get("Content-Encoding") == "gzip":
            data = gzip.decompress(data)
        return json.loads(data) if data else {}

    def _send_json(self, obj: Any, status: int = 200) -> None:
        body = json.dumps(obj).encode()
        hdrs = {}
        if ("gzip" in (self.headers.get("Accept-Encoding") or "")
                and len(body) > GZIP_FLOOR):
            body = gzip.compress(body, 5)
            hdrs["Content-Encoding"] = "gzip"
        if status >= 400:
            self.close_connection = True
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        for k, v in hdrs.items():
            self.send_header(k, v)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _route(self, method: str) -> None:
        path = unquote(urlsplit(self.path).path).rstrip("/") or "/"
        self.app.count(requests=1)
        route = route_family(path)
        t0 = time.perf_counter()
        try:
            with span("serve.request", cat="serve", method=method,
                      route=route):
                handler = self._resolve(method, path)
                if handler is None:
                    self._send_json({"error": f"no route {method} {path}"},
                                    status=404)
                    return
                handler()
        except EndpointUnavailable as exc:
            # the serving gate: quarantined/empty endpoints refuse traffic
            self.app.count(gate_refusals=1)
            self._send_json({"error": str(exc)}, status=503)
        except BitIdentityError as exc:
            self._send_json({"error": f"bit-identity: {exc}"}, status=500)
        except (ValueError, KeyError, json.JSONDecodeError) as exc:
            self._send_json({"error": str(exc)}, status=400)
        except ConnectionError:
            raise  # client went away mid-response; nothing to send
        except Exception as exc:  # noqa: BLE001 — daemon must not die
            self._send_json({"error": f"internal: {exc}"}, status=500)
        finally:
            self.app.observe_request(method, route,
                                     time.perf_counter() - t0)

    def _resolve(self, method: str, path: str):
        if path.startswith("/api/predict/"):
            name = path[len("/api/predict/"):]
            if not _safe_id(name) or method != "POST":
                return None
            return lambda: self._predict(name)
        table = {
            ("GET", "/api/ping"): self._ping,
            ("GET", "/api/endpoints"): self._endpoints,
            ("GET", "/api/stats"): self._stats,
            ("GET", "/api/metrics"): self._metrics,
            ("POST", "/api/refresh"): self._refresh,
        }
        return table.get((method, path))

    def do_GET(self) -> None:
        self._route("GET")

    def do_POST(self) -> None:
        self._route("POST")

    # -- routes --------------------------------------------------------------
    def _ping(self) -> None:
        self._send_json({"ok": True, "service": "mgit-serve",
                         "endpoints": sorted(self.app.router.endpoints)})

    def _endpoints(self) -> None:
        self._send_json(self.app.router.stats())

    def _stats(self) -> None:
        self._send_json(self.app.stats_json())

    def _metrics(self) -> None:
        # Prometheus text, NOT json — scrapers parse the exposition format
        body = self.app.metrics_text().encode()
        self.send_response(200)
        self.send_header("Content-Type",
                         "text/plain; version=0.0.4; charset=utf-8")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _predict(self, name: str) -> None:
        body = self._read_json()
        x = body.get("x")
        if x is not None:
            x = np.asarray(x, np.float32)
        result = self.app.router.predict(name, x)
        self.app.count(predictions=1)
        self._send_json(result)

    def _refresh(self) -> None:
        if self.app.watcher is None:
            self._send_json({"error": "no watcher configured"}, status=400)
            return
        self._send_json(self.app.watcher.poll())


class ServeServer(ThreadingHTTPServer):
    daemon_threads = True
    allow_reuse_address = True

    def __init__(self, app: ServeApp, host: str = "127.0.0.1",
                 port: int = 0) -> None:
        self.app = app
        super().__init__((host, port), ServeRequestHandler)

    @property
    def url(self) -> str:
        host, port = self.server_address[:2]
        return f"http://{host}:{port}"


def make_server(app: ServeApp, host: str = "127.0.0.1",
                port: int = 0) -> ServeServer:
    """Bind (port 0 picks an ephemeral one) without starting the loop."""
    return ServeServer(app, host=host, port=port)


def start_in_thread(app: ServeApp, host: str = "127.0.0.1", port: int = 0
                    ) -> Tuple[ServeServer, threading.Thread]:
    """Serve on a daemon thread; returns the bound server (``server.url``)."""
    server = make_server(app, host=host, port=port)
    thread = threading.Thread(target=server.serve_forever,
                              name="mgit-serve", daemon=True)
    thread.start()
    return server, thread
