"""Endpoint router: named endpoints -> branch heads, gated and hot-swapped.

The operational model is the pyxet/XetHub workflow: endpoints pin
*branches*, not node ids — "A/B testing between branches", and promoting a
model to production is a merge. Concretely (DESIGN.md §13):

* a **branch** is named by its root lineage node; the branch **head** is
  found by walking forward from that root — first along version edges
  (``version_children``), then into *join* nodes (provenance children with
  two or more parents, i.e. ``merge(x, y)``). Deriving a new model FROM a
  branch (one-parent provenance children) does not advance it; merging
  INTO it does, which is exactly what makes "promote = merge" work.
* every lineage publish re-resolves each endpoint; when a head moved, the
  new view is built **before** the pointer swap, so the swap itself is one
  pointer assignment under the endpoint lock — in-flight requests hold
  leases on the old view, which stays fully usable until drained.
* the diag quarantine flag (``repro.core.quarantine``) is a serving gate:
  a head that resolves to a quarantined node gets NO traffic — the
  endpoint keeps serving its last healthy view (reported as gate-blocked)
  or, with no prior view, refuses requests outright.
"""

from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

from repro.core.quarantine import is_quarantined
from repro.obs import REGISTRY, span
from repro.serve.pool import ModelPool, ResidentView

# process-wide drain accounting: a view fully drained (last lease released
# after a swap displaced it) is the moment its memory is reclaimable
_DRAINED = REGISTRY.counter(
    "mgit_router_views_drained",
    help="displaced views whose last in-flight lease has released")


class EndpointUnavailable(Exception):
    """No healthy resident view for this endpoint (gate or empty lineage)."""


def parse_endpoint_spec(spec: str) -> Dict[str, str]:
    """``name=branch:X`` | ``name=node:X`` | ``name=ref:m_...`` -> parts.

    ``branch`` re-resolves to the branch head on every lineage change;
    ``node`` pins one lineage node (still gate-checked); ``ref`` pins a raw
    manifest ref (no lineage doc, so no gate or hot swap)."""
    if "=" not in spec:
        raise ValueError(f"endpoint spec {spec!r} is not name=mode:target")
    name, _, rest = spec.partition("=")
    mode, _, target = rest.partition(":")
    if not target:
        mode, target = "branch", rest  # bare `prod=main` means branch:main
    if mode not in ("branch", "node", "ref"):
        raise ValueError(f"endpoint mode {mode!r} not branch|node|ref")
    if not name or not target:
        raise ValueError(f"endpoint spec {spec!r} is missing a name/target")
    return {"name": name, "mode": mode, "target": target}


def resolve_branch_head(nodes: Dict[str, Dict[str, Any]], branch: str) -> str:
    """Walk from the branch root to its current head (see module doc).

    Deterministic (candidates are taken in sorted order) and cycle-guarded;
    raises ``KeyError`` when the branch root is not in the lineage."""
    if branch not in nodes:
        raise KeyError(f"branch root {branch!r} not in lineage")
    cur, seen = branch, {branch}
    while True:
        doc = nodes[cur]
        step = next((v for v in sorted(doc.get("version_children", []))
                     if v in nodes and v not in seen), None)
        if step is None:
            step = next(
                (c for c in sorted(doc.get("children", []))
                 if c in nodes and c not in seen
                 and len(nodes[c].get("parents", [])) >= 2), None)
        if step is None:
            return cur
        seen.add(step)
        cur = step


class Endpoint:
    """One named route: current view + lease/drain accounting."""

    def __init__(self, name: str, mode: str, target: str) -> None:
        self.name = name
        self.mode = mode
        self.target = target
        self._lock = threading.Lock()
        self._view: Optional[ResidentView] = None
        self.node: Optional[str] = None
        self.gate_reason: Optional[str] = None
        self.swaps = 0
        self.last_swap_s = 0.0
        self._draining: List[ResidentView] = []

    @contextmanager
    def lease(self):
        """Yield the current view, held alive for the whole request.

        The lease is what makes swaps zero-drop: ``swap`` only moves the
        endpoint's pointer, so a view leased here stays valid (arrays,
        aliases and all) until this context exits."""
        with self._lock:
            if self._view is None:
                raise EndpointUnavailable(
                    f"endpoint {self.name!r} has no healthy model"
                    + (f" (gate: {self.gate_reason})"
                       if self.gate_reason else ""))
            view = self._view
            view.acquire()
        try:
            yield view
        finally:
            view.release()
            self._reap()

    def swap(self, view: ResidentView, node: Optional[str],
             took_s: float) -> None:
        with self._lock:
            old, self._view = self._view, view
            self.node = node
            self.gate_reason = None
            self.swaps += 1
            self.last_swap_s = took_s
            if old is not None and old is not view:
                self._draining.append(old)
        self._reap()

    def block(self, reason: str) -> None:
        """Gate: stop advancing; last healthy view (if any) keeps serving."""
        with self._lock:
            self.gate_reason = reason

    def _reap(self) -> None:
        with self._lock:
            still = [v for v in self._draining if v.active_leases > 0]
            drained = len(self._draining) - len(still)
            self._draining = still
        if drained:
            _DRAINED.inc(drained)

    @property
    def current_ref(self) -> Optional[str]:
        with self._lock:
            return self._view.ref if self._view is not None else None

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "name": self.name,
                "spec": f"{self.mode}:{self.target}",
                "node": self.node,
                "ref": self._view.ref if self._view else None,
                "gate": self.gate_reason,
                "swaps": self.swaps,
                "last_swap_s": round(self.last_swap_s, 6),
                "draining": len(self._draining),
                "active_leases": (self._view.active_leases
                                  if self._view else 0),
            }


class Router:
    """Maps endpoint names to resident views; re-resolves on refresh."""

    def __init__(self, pool: ModelPool, specs: List[str]) -> None:
        self.pool = pool
        self.endpoints: Dict[str, Endpoint] = {}
        for spec in specs:
            p = parse_endpoint_spec(spec)
            if p["name"] in self.endpoints:
                raise ValueError(f"duplicate endpoint {p['name']!r}")
            self.endpoints[p["name"]] = Endpoint(p["name"], p["mode"],
                                                 p["target"])
        self.etag: Optional[str] = None
        self.refreshes = 0

    def refresh(self, payload: Optional[Dict[str, Any]],
                etag: Optional[str] = None) -> Dict[str, Any]:
        """Re-resolve every endpoint against a lineage document.

        Builds any new view BEFORE swapping the endpoint pointer; a failed
        build or a quarantined head leaves the endpoint on its previous
        healthy view. Returns a per-endpoint report."""
        nodes = {n["name"]: n
                 for n in (payload or {}).get("nodes", [])}
        report: Dict[str, Any] = {}
        for ep in self.endpoints.values():
            try:
                report[ep.name] = self._refresh_one(ep, nodes)
            except Exception as exc:  # noqa: BLE001 — one endpoint failing
                ep.block(str(exc))    # must not take the others down
                report[ep.name] = {"status": "error", "error": str(exc)}
        self.etag = etag
        self.refreshes += 1
        return report

    def _refresh_one(self, ep: Endpoint,
                     nodes: Dict[str, Dict[str, Any]]) -> Dict[str, Any]:
        if ep.mode == "ref":
            ref, node = ep.target, None
        else:
            node = (resolve_branch_head(nodes, ep.target)
                    if ep.mode == "branch" else ep.target)
            doc = nodes.get(node)
            if doc is None:
                raise KeyError(f"node {node!r} not in lineage")
            if is_quarantined(doc):
                ep.block(f"node {node!r} is quarantined")
                return {"status": "gate_blocked", "node": node}
            ref = doc.get("artifact_ref")
            if not ref:
                raise ValueError(f"node {node!r} has no stored artifact")
        if ref == ep.current_ref:
            with ep._lock:
                ep.gate_reason = None
                ep.node = node
            return {"status": "unchanged", "node": node, "ref": ref}
        t0 = time.perf_counter()
        with span("endpoint.swap", cat="serve", endpoint=ep.name, ref=ref):
            view = self.pool.get(ref)  # built before the pointer moves
            ep.swap(view, node, time.perf_counter() - t0)
        return {"status": "swapped", "node": node, "ref": ref}

    # -- request path --------------------------------------------------------
    def predict(self, endpoint: str, x=None) -> Dict[str, Any]:
        ep = self.endpoints.get(endpoint)
        if ep is None:
            raise KeyError(f"no endpoint {endpoint!r}")
        with ep.lease() as view:
            y = view.probe(x)
            return {"endpoint": endpoint, "node": ep.node, "ref": view.ref,
                    "y": [float(v) for v in y.ravel()[:16]],
                    "mean": float(y.mean())}

    def stats(self) -> Dict[str, Any]:
        return {"etag": self.etag, "refreshes": self.refreshes,
                "endpoints": [ep.stats()
                              for ep in self.endpoints.values()]}
