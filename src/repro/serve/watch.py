"""Lineage watch loop: hot-swap endpoints when a publish lands.

Two sources, one contract — ``fetch() -> (payload, etag)``:

* :class:`LocalLineageSource` reads ``lineage.json`` of a repo directory
  and derives the etag with the same canonical content hash the remote
  protocol uses (``lineage_etag``), so a local commit and a hub publish of
  the same document produce the same etag;
* :class:`HubLineageSource` polls the hub's ETag'd ``GET /api/lineage``
  through the existing :class:`HttpTransport` — no new wire protocol.

:class:`LineageWatcher` compares etags and only re-resolves the router on
an actual change; ``poll()`` is also callable directly (the serve HTTP
layer exposes it as ``POST /api/refresh`` so tests and CI don't have to
wait out the poll interval).
"""

from __future__ import annotations

import json
import logging
import os
import threading
from typing import Any, Dict, Optional, Tuple

from repro.obs import REGISTRY
from repro.remote.transport import ETAG_ABSENT, lineage_etag
from repro.serve.router import Router

logger = logging.getLogger("repro.serve.watch")


class LocalLineageSource:
    def __init__(self, root: str) -> None:
        self.root = root

    def fetch(self) -> Tuple[Optional[Dict[str, Any]], str]:
        path = os.path.join(self.root, "lineage.json")
        if not os.path.exists(path):
            return None, ETAG_ABSENT
        with open(path) as f:
            payload = json.load(f)
        return payload, lineage_etag(payload)

    def describe(self) -> str:
        return f"local:{self.root}"


class HubLineageSource:
    def __init__(self, url: str, token: Optional[str] = None) -> None:
        from repro.remote.http import HttpTransport
        self.url = url
        self.transport = HttpTransport(url, token=token)

    def fetch(self) -> Tuple[Optional[Dict[str, Any]], str]:
        return self.transport.fetch_lineage_versioned()

    def describe(self) -> str:
        return f"hub:{self.url}"


class LineageWatcher:
    """Etag-compare poll loop driving :meth:`Router.refresh`."""

    def __init__(self, source, router: Router,
                 interval_s: float = 1.0) -> None:
        self.source = source
        self.router = router
        self.interval_s = interval_s
        self.last_etag: Optional[str] = None
        self.polls = 0
        self.changes = 0
        # failure visibility (ISSUE 8): a flaky source must not end the
        # loop, but it must not be silent either — failures count into the
        # registry, the latest error is inspectable via stats(), and the
        # FIRST failure after a healthy poll logs at WARN (one line per
        # outage, not one per tick).
        self.last_error: Optional[str] = None
        self.consecutive_failures = 0
        self._failures = REGISTRY.counter(
            "mgit_watch_poll_failures",
            help="lineage watcher polls that raised",
            source=source.describe())
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def poll(self) -> Dict[str, Any]:
        """One fetch+compare; refreshes the router only on a new etag."""
        payload, etag = self.source.fetch()
        self.polls += 1
        self.last_error = None
        self.consecutive_failures = 0
        if etag == self.last_etag:
            return {"changed": False, "etag": etag}
        # a publish may have been committed by another process (CLI merge,
        # sync pull): re-index the store so the new refs are readable here
        reload_store = getattr(self.router.pool.store, "reload", None)
        if reload_store is not None:
            reload_store()
        report = self.router.refresh(payload, etag=etag)
        self.last_etag = etag
        self.changes += 1
        return {"changed": True, "etag": etag, "endpoints": report}

    def _record_failure(self, exc: Exception) -> None:
        first = self.consecutive_failures == 0
        self.consecutive_failures += 1
        self.last_error = f"{type(exc).__name__}: {exc}"
        self._failures.inc()
        if first:
            logger.warning("lineage watch poll of %s failed: %s "
                           "(retrying every %.1fs)",
                           self.source.describe(), self.last_error,
                           self.interval_s)

    def run(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self.poll()
            except Exception as exc:  # noqa: BLE001 — a flaky fetch must
                self._record_failure(exc)  # not end the loop; the next
                                           # tick retries

    def start(self) -> "LineageWatcher":
        self._thread = threading.Thread(target=self.run, name="mgit-watch",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)

    def stats(self) -> Dict[str, Any]:
        return {"source": self.source.describe(), "polls": self.polls,
                "changes": self.changes, "etag": self.last_etag,
                "interval_s": self.interval_s,
                "poll_failures": int(self._failures.get()),
                "consecutive_failures": self.consecutive_failures,
                "last_error": self.last_error}
