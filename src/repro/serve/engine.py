"""Batched serving: prefill + greedy decode over the unified model API."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import decode_step, prefill


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, batch):
        return prefill(cfg, params, batch, max_len=max_len)
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """serve_step(params, cache, token (B,1), pos) -> (next_token, logits, cache')."""

    def serve_step(params, cache, token, pos):
        logits, new_cache = decode_step(cfg, params, token, cache, pos)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_token, logits, new_cache

    return serve_step


class ServeEngine:
    """Minimal batched engine: prefill once, then greedy decode N tokens."""

    def __init__(self, cfg: ModelConfig, params, max_len: int):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(make_prefill_step(cfg, max_len))
        self._step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    def generate(self, batch: Dict[str, jnp.ndarray], n_tokens: int):
        last_logits, cache = self._prefill(self.params, batch)
        token = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]
        pos = jnp.asarray(batch["tokens"].shape[1], jnp.int32)
        out = [token]
        for _ in range(n_tokens - 1):
            token, _, cache = self._step(self.params, cache, token, pos)
            pos = pos + 1
            out.append(token)
        return jnp.concatenate(out, axis=1)
