"""Batched serving: prefill + greedy decode over the unified model API."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import decode_step, prefill


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, batch):
        return prefill(cfg, params, batch, max_len=max_len)
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """serve_step(params, cache, token (B,1), pos) -> (next_token, logits, cache')."""

    def serve_step(params, cache, token, pos):
        logits, new_cache = decode_step(cfg, params, token, cache, pos)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_token, logits, new_cache

    return serve_step


def batch_lengths(batch: Dict[str, jnp.ndarray]) -> Optional[jnp.ndarray]:
    """Per-sequence prompt lengths from ``lengths`` (B,) or ``mask`` (B, S).

    Returns ``None`` when neither is present (the batch is declared
    unpadded). Lengths are clamped to [1, S]: an empty prompt still
    occupies one slot so the decode recursion has a defined position."""
    if "lengths" in batch:
        lengths = jnp.asarray(batch["lengths"], jnp.int32)
    elif "mask" in batch:
        lengths = jnp.sum(batch["mask"] > 0, axis=-1).astype(jnp.int32)
    else:
        return None
    return jnp.clip(lengths, 1, batch["tokens"].shape[1])


def left_align(tokens: jnp.ndarray, lengths: jnp.ndarray,
               pad_id: int = 0) -> jnp.ndarray:
    """Shift each row right so its last real token sits in the last column.

    The decode cache is positional: prefill writes prompt K/V at physical
    slots ``[0, S)`` and the next token lands at slot ``S`` for the whole
    batch. Right-padded ragged rows break that — their true last token is
    at ``lengths[i] - 1``, so last-column logits belong to padding. Left-
    aligning restores one shared layout: every row ends at column
    ``S - 1``, and the shared position counter is uniformly correct."""
    B, S = tokens.shape
    src = jnp.arange(S)[None, :] - (S - lengths)[:, None]
    gathered = jnp.take_along_axis(tokens, jnp.clip(src, 0, S - 1), axis=1)
    return jnp.where(src >= 0, gathered, pad_id)


class ServeEngine:
    """Minimal batched engine: prefill once, then greedy decode N tokens.

    Ragged batches are declared via ``batch["lengths"]`` (B,) or a 0/1
    ``batch["mask"]`` (B, S) and are normalized by **left-alignment**
    (the standard decoder-only padding side): per-sequence last-token
    logits become the physical last column and one shared decode position
    serves the whole batch. Contract: a row of length L generated inside a
    ragged width-S batch is identical to generating that row alone at the
    same width — and a full-width row is identical to the unpadded run.
    (Left pads are attended like any prefix token — the model stack has no
    padding mask — so left-padded rows approximate, rather than replicate,
    their unpadded runs; positions index physical cache slots.)
    """

    def __init__(self, cfg: ModelConfig, params, max_len: int):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(make_prefill_step(cfg, max_len))
        self._step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    def generate(self, batch: Dict[str, jnp.ndarray], n_tokens: int):
        """Greedy-decode ``n_tokens`` tokens; returns (B, n_tokens) int32.

        ``n_tokens=0`` returns an empty (B, 0) array without touching the
        model; ``n_tokens=1`` is exactly one prefill and no decode steps."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        if n_tokens <= 0:
            return jnp.zeros((B, 0), jnp.int32)
        lengths = batch_lengths(batch)
        if lengths is not None:
            batch = {k: v for k, v in batch.items() if k != "mask"}
            batch["tokens"] = left_align(tokens, lengths)
        last_logits, cache = self._prefill(self.params, batch)
        token = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]
        # every row's prompt now ends at physical slot S - 1, so the first
        # decoded token lands at slot S for the whole batch
        pos = jnp.asarray(S, jnp.int32)
        out = [token]
        for _ in range(n_tokens - 1):
            token, _, cache = self._step(self.params, cache, token, pos)
            pos = pos + 1
            out.append(token)
        return jnp.concatenate(out, axis=1)
